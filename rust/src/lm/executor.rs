//! The executor abstraction the compressor and coordinator program against.
//!
//! An executor owns `lanes()` independent autoregressive streams. Each
//! [`LmExecutor::step`] feeds one token per lane and returns each lane's
//! next-token logits ([`LmExecutor::step_into`] is the allocation-free
//! variant the hot loops use). [`LmExecutor::encode_logits`] is the bulk
//! encode path: lane inputs in, logits for every position out — engines
//! with a one-shot batched forward (PJRT forward) override it; everyone
//! else inherits the default stepping fallback, so the compressor contains
//! no per-engine dispatch at all (it holds a `Box<dyn LmExecutor>`).
//!
//! Both compression and decompression drive the SAME executor interface,
//! which guarantees the probability streams match bit-for-bit (the
//! container records the executor kind to prevent cross-executor decode).
//!
//! Implementations:
//! * [`crate::lm::NativeExecutor`] — pure rust, batched, with a persistent
//!   worker-thread pool (`with_threads`) and `Arc`-shared weights so
//!   replicas cost no extra tensor memory.
//! * [`crate::runtime::PjrtStepExecutor`] — the lowered `decode_step` HLO.
//! * [`crate::runtime::PjrtForwardExecutor`] — batched `forward` HLO with
//!   prefix replay (fast compression path; see `compress/llm.rs`).

use crate::lm::config::{LmConfig, VOCAB};
use crate::tokenizer::vocab::PAD;
use crate::Result;

/// Which engine produced/consumes a probability stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    Native,
    PjrtStep,
    PjrtForward,
}

impl ExecutorKind {
    pub fn as_flag(self) -> u16 {
        match self {
            ExecutorKind::Native => 0,
            ExecutorKind::PjrtStep => 1,
            ExecutorKind::PjrtForward => 2,
        }
    }

    pub fn from_flag(flag: u16) -> Result<Self> {
        Ok(match flag {
            0 => ExecutorKind::Native,
            1 => ExecutorKind::PjrtStep,
            2 => ExecutorKind::PjrtForward,
            other => anyhow::bail!("unknown executor flag {other}"),
        })
    }

    /// Two kinds are stream-compatible iff their logits are bit-identical.
    /// PjrtStep and PjrtForward run different HLO reductions — NOT compatible.
    pub fn compatible(self, other: ExecutorKind) -> bool {
        self == other
    }
}

/// A batch of autoregressive LM streams.
pub trait LmExecutor {
    fn config(&self) -> &'static LmConfig;
    fn kind(&self) -> ExecutorKind;

    /// Number of parallel lanes.
    fn lanes(&self) -> usize;

    /// Human-readable kernel dispatch tier this executor resolved at load
    /// (`"scalar"` / `"avx2"` / `"neon"` for the native engine,
    /// `"pjrt-hlo"` for lowered engines). Diagnostic only — never part of
    /// the stream contract, since tiers are bit-identical by construction.
    fn kernel_tier(&self) -> &'static str {
        "n/a"
    }

    /// Reset every lane to position 0 (start of a new chunk batch).
    fn reset(&mut self);

    /// Feed one token per lane; returns logits `[lanes * VOCAB]` row-major.
    fn step(&mut self, tokens: &[u32]) -> Result<Vec<f32>>;

    /// Like [`Self::step`] but writes into a caller-owned buffer of
    /// `lanes * VOCAB`. Engines with preallocated scratch (the native one)
    /// override this to make steady-state stepping allocation-free; the
    /// default delegates to [`Self::step`].
    fn step_into(&mut self, tokens: &[u32], out: &mut [f32]) -> Result<()> {
        let logits = self.step(tokens)?;
        if out.len() != logits.len() {
            anyhow::bail!("step_into expects out buffer of {}, got {}", logits.len(), out.len());
        }
        out.copy_from_slice(&logits);
        Ok(())
    }

    /// Bulk logits for encode: lane inputs (BOS + chunk bytes), logits for
    /// the first `n_positions` positions per lane, `[lanes_in * n_positions
    /// * VOCAB]` row-major. The default resets the executor and steps
    /// position by position (padding absent lanes/positions with PAD);
    /// engines with a one-shot batched forward override it.
    fn encode_logits(&mut self, lanes: &[Vec<u32>], n_positions: usize) -> Result<Vec<f32>> {
        self.reset();
        let n_lanes = self.lanes();
        if lanes.len() > n_lanes {
            anyhow::bail!("{} chunk lanes > {} engine lanes", lanes.len(), n_lanes);
        }
        let mut out = vec![0.0f32; lanes.len() * n_positions * VOCAB];
        let mut step_logits = vec![0.0f32; n_lanes * VOCAB];
        let mut toks = vec![PAD; n_lanes];
        for t in 0..n_positions {
            for (l, tok) in toks.iter_mut().enumerate() {
                *tok = lanes.get(l).and_then(|lane| lane.get(t)).copied().unwrap_or(PAD);
            }
            self.step_into(&toks, &mut step_logits)?;
            for l in 0..lanes.len() {
                let src = &step_logits[l * VOCAB..(l + 1) * VOCAB];
                let dst = (l * n_positions + t) * VOCAB;
                out[dst..dst + VOCAB].copy_from_slice(src);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;
    use crate::lm::native::NativeExecutor;
    use crate::lm::weights::Weights;
    use crate::tokenizer::vocab::BOS;

    #[test]
    fn executor_flags_roundtrip() {
        for k in [ExecutorKind::Native, ExecutorKind::PjrtStep, ExecutorKind::PjrtForward] {
            assert_eq!(ExecutorKind::from_flag(k.as_flag()).unwrap(), k);
        }
        assert!(ExecutorKind::from_flag(99).is_err());
    }

    #[test]
    fn compatibility_is_identity() {
        assert!(ExecutorKind::Native.compatible(ExecutorKind::Native));
        assert!(!ExecutorKind::PjrtStep.compatible(ExecutorKind::PjrtForward));
    }

    #[test]
    fn default_encode_logits_matches_manual_stepping() {
        let cfg = by_name("nano").unwrap();
        let w = Weights::random(cfg, 20);
        let mut ex = NativeExecutor::new(cfg, w.clone(), 2);
        let lanes = vec![vec![BOS, 72, 101], vec![BOS, 104]];
        let bulk = ex.encode_logits(&lanes, 3).unwrap();
        assert_eq!(bulk.len(), 2 * 3 * VOCAB);

        // Manual replay with the same padding convention.
        let mut ex2 = NativeExecutor::new(cfg, w, 2);
        for t in 0..3usize {
            let toks: Vec<u32> = (0..2)
                .map(|l| lanes[l].get(t).copied().unwrap_or(PAD))
                .collect();
            let logits = ex2.step(&toks).unwrap();
            for l in 0..2 {
                assert_eq!(
                    logits[l * VOCAB..(l + 1) * VOCAB],
                    bulk[(l * 3 + t) * VOCAB..(l * 3 + t + 1) * VOCAB],
                    "lane {l} pos {t}"
                );
            }
        }

        // Over-wide chunk batches are rejected.
        let three = vec![vec![BOS], vec![BOS], vec![BOS]];
        assert!(ex.encode_logits(&three, 1).is_err());
    }
}
