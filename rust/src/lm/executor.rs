//! The executor abstraction the compressor and coordinator program against.
//!
//! An executor owns `lanes()` independent autoregressive streams. Each
//! [`LmExecutor::step`] feeds one token per lane and returns each lane's
//! next-token logits. Both compression and decompression drive the SAME
//! executor interface, which guarantees the probability streams match
//! bit-for-bit (the container records the executor kind to prevent
//! cross-executor decode).
//!
//! Implementations:
//! * [`crate::lm::NativeExecutor`] — pure rust, per-token.
//! * [`crate::runtime::PjrtStepExecutor`] — the lowered `decode_step` HLO.
//! * [`crate::runtime::PjrtForwardExecutor`] — batched `forward` HLO with
//!   prefix replay (fast compression path; see `compress/llm.rs`).

use crate::lm::config::LmConfig;
use crate::Result;

/// Which engine produced/consumes a probability stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    Native,
    PjrtStep,
    PjrtForward,
}

impl ExecutorKind {
    pub fn as_flag(self) -> u16 {
        match self {
            ExecutorKind::Native => 0,
            ExecutorKind::PjrtStep => 1,
            ExecutorKind::PjrtForward => 2,
        }
    }

    pub fn from_flag(flag: u16) -> Result<Self> {
        Ok(match flag {
            0 => ExecutorKind::Native,
            1 => ExecutorKind::PjrtStep,
            2 => ExecutorKind::PjrtForward,
            other => anyhow::bail!("unknown executor flag {other}"),
        })
    }

    /// Two kinds are stream-compatible iff their logits are bit-identical.
    /// PjrtStep and PjrtForward run different HLO reductions — NOT compatible.
    pub fn compatible(self, other: ExecutorKind) -> bool {
        self == other
    }
}

/// A batch of autoregressive LM streams.
pub trait LmExecutor {
    fn config(&self) -> &'static LmConfig;
    fn kind(&self) -> ExecutorKind;

    /// Number of parallel lanes.
    fn lanes(&self) -> usize;

    /// Reset every lane to position 0 (start of a new chunk batch).
    fn reset(&mut self);

    /// Feed one token per lane; returns logits `[lanes * VOCAB]` row-major.
    fn step(&mut self, tokens: &[u32]) -> Result<Vec<f32>>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executor_flags_roundtrip() {
        for k in [ExecutorKind::Native, ExecutorKind::PjrtStep, ExecutorKind::PjrtForward] {
            assert_eq!(ExecutorKind::from_flag(k.as_flag()).unwrap(), k);
        }
        assert!(ExecutorKind::from_flag(99).is_err());
    }

    #[test]
    fn compatibility_is_identity() {
        assert!(ExecutorKind::Native.compatible(ExecutorKind::Native));
        assert!(!ExecutorKind::PjrtStep.compatible(ExecutorKind::PjrtForward));
    }
}
