//! AVX2 kernels (x86_64). Bit-identical to `scalar` by construction:
//!
//! * f32 dots keep 8 lane accumulators in one `__m256` and combine with
//!   the exact `extractf128` / `movehl` / `shuffle` sequence the scalar
//!   [`super::scalar::combine8`] spells out. **No FMA** — every step is
//!   an explicit `_mm256_mul_ps` followed by `_mm256_add_ps`, matching
//!   the scalar `lanes[j] += a * b` two-op sequence.
//! * Remainder lanes are staged through zeroed stack buffers (never
//!   loading past a slice end); the padded `x * 0.0` products add `±0.0`
//!   to accumulators that are provably never `-0.0`, a bitwise no-op.
//! * i8 dots widen to i16 (`cvtepi8_epi16`); `madd_epi16` multiplies
//!   and sums adjacent pairs directly into i32 lanes (pair sums reach
//!   2·127², past i16 — the i32 widening is what keeps this exact);
//!   lane sums accumulate in i32, where order is free.
//!
//! Callers must verify `avx2` support (done once at model load); every
//! `unsafe fn` here is `#[target_feature(enable = "avx2")]`. Inside
//! these bodies the value-only intrinsics are safe; the explicit
//! `unsafe` blocks below mark exactly the pointer loads/stores, each
//! with the bound that keeps it in-range.

use super::{PanelF32, PanelI8, F32_LANES, F32_PANEL_COLS, I8_LANES};
use core::arch::x86_64::*;

/// Canonical tree combine of 8 f32 lanes — identical adds, identical
/// order to `scalar::combine8`. Value-only (no memory access), so it is
/// a safe `#[target_feature]` fn: callable without `unsafe` from the
/// AVX2 kernels, never from generic code.
#[inline]
#[target_feature(enable = "avx2")]
fn hsum8(acc: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(acc);
    let hi = _mm256_extractf128_ps(acc, 1);
    let s = _mm_add_ps(lo, hi); // s_k = l_k + l_{k+4}
    let pair = _mm_add_ps(s, _mm_movehl_ps(s, s)); // (s0+s2, s1+s3, ..)
    let t = _mm_add_ss(pair, _mm_shuffle_ps(pair, pair, 1)); // (s0+s2)+(s1+s3)
    _mm_cvtss_f32(t)
}

/// Exact horizontal i32 sum (order-free). Value-only, safe to call from
/// AVX2 contexts (see `hsum8`).
#[inline]
#[target_feature(enable = "avx2")]
fn hsum_i32(acc: __m256i) -> i32 {
    let lo = _mm256_castsi256_si128(acc);
    let hi = _mm256_extracti128_si256(acc, 1);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0x4E));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0x01));
    _mm_cvtsi128_si32(s)
}

/// # Safety
/// Requires AVX2 (checked once at model load); `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = _mm256_setzero_ps();
    let mut i = 0;
    while i + F32_LANES <= n {
        // SAFETY: i + F32_LANES <= n and both slices hold n elements,
        // so each unaligned 8-lane load stays in bounds.
        let va = unsafe { _mm256_loadu_ps(a.as_ptr().add(i)) };
        // SAFETY: same bound as `va` above.
        let vb = unsafe { _mm256_loadu_ps(b.as_ptr().add(i)) };
        acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        i += F32_LANES;
    }
    if i < n {
        let mut ta = [0.0f32; F32_LANES];
        let mut tb = [0.0f32; F32_LANES];
        ta[..n - i].copy_from_slice(&a[i..]);
        tb[..n - i].copy_from_slice(&b[i..]);
        // SAFETY: ta/tb are exactly F32_LANES-wide stack arrays.
        let (va, vb) = unsafe { (_mm256_loadu_ps(ta.as_ptr()), _mm256_loadu_ps(tb.as_ptr())) };
        acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
    }
    hsum8(acc)
}

/// # Safety
/// Requires AVX2 (checked once at model load); slice geometry per
/// `super::matmul_f32` (xs is n×d_in, ys is n×d_out, p packs d_out
/// columns of d_in_pad-padded weights).
#[target_feature(enable = "avx2")]
pub unsafe fn matmul_f32_panel(
    n: usize,
    d_in: usize,
    d_out: usize,
    xs: &[f32],
    p: &PanelF32,
    ys: &mut [f32],
) {
    let full = d_in / F32_LANES;
    let rem = d_in % F32_LANES;
    let n_panels = p.data.len() / (F32_PANEL_COLS * p.d_in_pad);
    for l in 0..n {
        let x = &xs[l * d_in..(l + 1) * d_in];
        let mut xt = [0.0f32; F32_LANES];
        if rem > 0 {
            xt[..rem].copy_from_slice(&x[full * F32_LANES..]);
        }
        let y = &mut ys[l * d_out..(l + 1) * d_out];
        for pi in 0..n_panels {
            // SAFETY: panel pi spans F32_PANEL_COLS * d_in_pad floats of
            // p.data (pi < n_panels bounds it); group offsets step by
            // F32_LANES * F32_PANEL_COLS up to d_in_pad, staying inside
            // the panel. x loads cover k * F32_LANES + 8 <= d_in; the
            // tail reads the F32_LANES-wide zero-padded xt instead of x.
            let (a0, a1, a2, a3) = unsafe {
                let base = p.data.as_ptr().add(pi * F32_PANEL_COLS * p.d_in_pad);
                let mut a0 = _mm256_setzero_ps();
                let mut a1 = _mm256_setzero_ps();
                let mut a2 = _mm256_setzero_ps();
                let mut a3 = _mm256_setzero_ps();
                for k in 0..full {
                    let xv = _mm256_loadu_ps(x.as_ptr().add(k * F32_LANES));
                    let g = base.add(k * F32_LANES * F32_PANEL_COLS);
                    a0 = _mm256_add_ps(a0, _mm256_mul_ps(xv, _mm256_loadu_ps(g)));
                    a1 = _mm256_add_ps(a1, _mm256_mul_ps(xv, _mm256_loadu_ps(g.add(8))));
                    a2 = _mm256_add_ps(a2, _mm256_mul_ps(xv, _mm256_loadu_ps(g.add(16))));
                    a3 = _mm256_add_ps(a3, _mm256_mul_ps(xv, _mm256_loadu_ps(g.add(24))));
                }
                if rem > 0 {
                    let xv = _mm256_loadu_ps(xt.as_ptr());
                    let g = base.add(full * F32_LANES * F32_PANEL_COLS);
                    a0 = _mm256_add_ps(a0, _mm256_mul_ps(xv, _mm256_loadu_ps(g)));
                    a1 = _mm256_add_ps(a1, _mm256_mul_ps(xv, _mm256_loadu_ps(g.add(8))));
                    a2 = _mm256_add_ps(a2, _mm256_mul_ps(xv, _mm256_loadu_ps(g.add(16))));
                    a3 = _mm256_add_ps(a3, _mm256_mul_ps(xv, _mm256_loadu_ps(g.add(24))));
                }
                (a0, a1, a2, a3)
            };
            let j0 = pi * F32_PANEL_COLS;
            let dots = [hsum8(a0), hsum8(a1), hsum8(a2), hsum8(a3)];
            let live = F32_PANEL_COLS.min(d_out - j0);
            for r in 0..live {
                y[j0 + r] += dots[r];
            }
        }
    }
}

/// # Safety
/// Requires AVX2 (checked once at model load); `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let full = n / I8_LANES;
    let rem = n % I8_LANES;
    let mut acc = _mm256_setzero_si256();
    for k in 0..full {
        // SAFETY: (k + 1) * I8_LANES <= n and both slices hold n bytes,
        // so each 16-byte load is in bounds.
        let (va, vb) = unsafe {
            let pa = a.as_ptr().add(k * I8_LANES) as *const __m128i;
            let pb = b.as_ptr().add(k * I8_LANES) as *const __m128i;
            (_mm_loadu_si128(pa), _mm_loadu_si128(pb))
        };
        let (va, vb) = (_mm256_cvtepi8_epi16(va), _mm256_cvtepi8_epi16(vb));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
    }
    if rem > 0 {
        let mut ta = [0i8; I8_LANES];
        let mut tb = [0i8; I8_LANES];
        ta[..rem].copy_from_slice(&a[full * I8_LANES..]);
        tb[..rem].copy_from_slice(&b[full * I8_LANES..]);
        // SAFETY: ta/tb are exactly I8_LANES (16) bytes on the stack.
        let (va, vb) = unsafe {
            (
                _mm_loadu_si128(ta.as_ptr() as *const __m128i),
                _mm_loadu_si128(tb.as_ptr() as *const __m128i),
            )
        };
        let (va, vb) = (_mm256_cvtepi8_epi16(va), _mm256_cvtepi8_epi16(vb));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
    }
    hsum_i32(acc)
}

/// # Safety
/// Requires AVX2 (checked once at model load); slice geometry per
/// `super::matmul_i8` (qx is n×d_in, ys is n×d_out, p rows are
/// d_in_pad-padded and zero-filled past d_in).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn matmul_i8_panel(
    n: usize,
    d_in: usize,
    d_out: usize,
    p: &PanelI8,
    ws: &[f32],
    qx: &[i8],
    sx: &[f32],
    ys: &mut [f32],
) {
    let full = d_in / I8_LANES;
    let rem = d_in % I8_LANES;
    for l in 0..n {
        let s = sx[l];
        if s == 0.0 {
            continue;
        }
        let q = &qx[l * d_in..(l + 1) * d_in];
        let mut qt = [0i8; I8_LANES];
        if rem > 0 {
            qt[..rem].copy_from_slice(&q[full * I8_LANES..]);
        }
        let y = &mut ys[l * d_out..(l + 1) * d_out];
        for j in 0..d_out {
            // SAFETY: row j spans d_in_pad bytes of p.data (j < d_out
            // rows are packed back to back); k * I8_LANES + 16 <=
            // d_in <= d_in_pad bounds the weight and activation loads.
            // The tail loads the 16-byte zero-padded qt, and the weight
            // row is zero-filled past d_in, so its full-width tail load
            // is in-bounds and exact.
            let acc = unsafe {
                let row = p.data.as_ptr().add(j * p.d_in_pad);
                let mut acc = _mm256_setzero_si256();
                for k in 0..full {
                    let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                        q.as_ptr().add(k * I8_LANES) as *const __m128i
                    ));
                    let vb = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                        row.add(k * I8_LANES) as *const __m128i
                    ));
                    acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
                }
                if rem > 0 {
                    let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(qt.as_ptr() as *const __m128i));
                    let vb = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                        row.add(full * I8_LANES) as *const __m128i
                    ));
                    acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
                }
                acc
            };
            y[j] += s * ws[j] * hsum_i32(acc) as f32;
        }
    }
}

/// # Safety
/// Requires AVX2 (checked once at model load); `x.len() == y.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let va = _mm256_set1_ps(a);
    let mut i = 0;
    while i + F32_LANES <= n {
        // SAFETY: i + F32_LANES <= n == x.len() == y.len() bounds both
        // loads and the store.
        unsafe {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, _mm256_mul_ps(va, xv)));
        }
        i += F32_LANES;
    }
    while i < n {
        y[i] += a * x[i];
        i += 1;
    }
}

/// # Safety
/// Requires AVX2 (checked once at model load); `xs` is n×d, `qx` is
/// n×d, `sx` holds n scales.
#[target_feature(enable = "avx2")]
pub unsafe fn quantize_lanes(n: usize, d: usize, xs: &[f32], qx: &mut [i8], sx: &mut [f32]) {
    let sign = _mm256_set1_ps(-0.0);
    for l in 0..n {
        let row = &xs[l * d..(l + 1) * d];
        // Max-abs: vector max then horizontal max, folding the tail in
        // scalar — `max` over non-negative values is order-free.
        let mut vm = _mm256_setzero_ps();
        let mut i = 0;
        while i + F32_LANES <= d {
            // SAFETY: i + F32_LANES <= d == row.len().
            let v = unsafe { _mm256_loadu_ps(row.as_ptr().add(i)) };
            vm = _mm256_max_ps(vm, _mm256_andnot_ps(sign, v));
            i += F32_LANES;
        }
        let lo = _mm256_castps256_ps128(vm);
        let hi = _mm256_extractf128_ps(vm, 1);
        let m = _mm_max_ps(lo, hi);
        let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
        let m = _mm_max_ss(m, _mm_shuffle_ps(m, m, 1));
        let mut maxabs = _mm_cvtss_f32(m);
        for &v in &row[i..] {
            maxabs = maxabs.max(v.abs());
        }

        let q = &mut qx[l * d..(l + 1) * d];
        if maxabs == 0.0 {
            sx[l] = 0.0;
            q.fill(0);
            continue;
        }
        let scale = maxabs / 127.0;
        sx[l] = scale;
        let inv = 1.0 / scale;

        // round(t) == trunc(t + copysign(0.5, t)) in-domain (|t| ≤ 127),
        // so the cvtt truncation below matches `scalar::quantize_one`.
        let vinv = _mm256_set1_ps(inv);
        let vhalf = _mm256_set1_ps(0.5);
        let cmin = _mm256_set1_epi32(-127);
        let cmax = _mm256_set1_epi32(127);
        let mut i = 0;
        while i + F32_LANES <= d {
            // SAFETY: i + F32_LANES <= d == row.len().
            let rv = unsafe { _mm256_loadu_ps(row.as_ptr().add(i)) };
            let t = _mm256_mul_ps(rv, vinv);
            let half = _mm256_or_ps(vhalf, _mm256_and_ps(t, sign));
            let r = _mm256_cvttps_epi32(_mm256_add_ps(t, half));
            let c = _mm256_min_epi32(_mm256_max_epi32(r, cmin), cmax);
            // Pack 8 i32 -> 8 i8 (values already in [-127, 127]).
            let p16 = _mm256_packs_epi32(c, c);
            let p8 = _mm256_packs_epi16(p16, p16);
            let lo4 = _mm256_extract_epi32(p8, 0) as u32 as u64;
            let hi4 = _mm256_extract_epi32(p8, 4) as u32 as u64;
            let bytes = (lo4 | (hi4 << 32)).to_le_bytes();
            for (dst, &b) in q[i..i + F32_LANES].iter_mut().zip(bytes.iter()) {
                *dst = b as i8;
            }
            i += F32_LANES;
        }
        for (qi, &v) in q[i..].iter_mut().zip(&row[i..]) {
            *qi = super::scalar::quantize_one(v, inv);
        }
    }
}
