//! SIMD kernel layer: dispatch-at-load vector matmuls, bit-exact by
//! construction.
//!
//! Every hot loop of the native engine (projection matmuls, attention
//! score/value dots, the weight-tied head, and activation quantization)
//! routes through the entry points in this module. A [`KernelTier`] is
//! resolved **once at model load** (stored in
//! [`crate::lm::weights::ResolvedPlan`]) and passed down to every call,
//! so there is no per-call feature detection and exactly one
//! implementation per (dtype, tier).
//!
//! # The bit-exactness contract
//!
//! Containers must stay byte-identical across `{scalar, avx2, neon} ×
//! {replicas, threads, lanes}`. Two mechanisms make that hold *by
//! construction* rather than by tolerance:
//!
//! * **i8×i8 dots are exactly associative.** Products are at most
//!   `127 * 127` and rows at most `MAX_D_IN` long, so the i32 accumulator
//!   is bounded by `MAX_D_IN * 127 * 127 ≪ i32::MAX` — integer addition
//!   never overflows and is order-free, so any lane width produces the
//!   same i32 (and therefore the same f32 after the single
//!   `sx * ws[j] * acc as f32` epilogue).
//! * **f32 dots use one fixed tree-order reduction** ([`F32_LANES`] = 8
//!   virtual lanes, combined as `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`),
//!   implemented *verbatim* by the scalar fallback and mapped 1:1 onto
//!   the natural AVX2/NEON horizontal-add sequences. No FMA is ever
//!   emitted (vector paths use explicit mul-then-add intrinsics; Rust
//!   never contracts scalar `a * b + c`), so scalar and vector tiers
//!   agree bit for bit.
//!
//! Zero padding is free: the lane accumulators start at `+0.0` and can
//! never become `-0.0` (a round-to-nearest sum is `-0.0` only when both
//! addends are `-0.0`, and products contributed by padding are
//! `x * 0.0 = ±0.0` added to a non-`-0.0` accumulator — a bitwise
//! no-op). Padded vector blocks therefore equal the scalar remainder
//! loop exactly.
//!
//! # Panel layout
//!
//! Row-major `[d_in, d_out]` weights make the per-output dot stride
//! `d_out` floats. [`PanelF32`]/[`PanelI8`] are deterministic transposed
//! copies built at load from the unchanged `.lmz` bytes (never
//! serialized): the f32 panel interleaves [`F32_PANEL_COLS`] output
//! columns in [`F32_LANES`]-wide blocks so one pass streams contiguous
//! memory while producing four outputs; the i8 panel stores one
//! contiguous zero-padded row per output. See `docs/kernels.md` for the
//! exact index maps.

use crate::Result;
use anyhow::bail;

pub(crate) mod scalar;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

/// Virtual f32 lane count: the fixed-tree dot accumulates into 8 lanes
/// regardless of tier (one `__m256` on AVX2, two `float32x4_t` on NEON,
/// a `[f32; 8]` in the scalar fallback).
pub const F32_LANES: usize = 8;

/// Output columns interleaved per f32 panel (4 independent accumulators
/// per pass keeps the FP add chains short enough to hide latency).
pub const F32_PANEL_COLS: usize = 4;

/// i8 block width: one 128-bit load of quantized activations.
pub const I8_LANES: usize = 16;

/// Environment override for the dispatch tier, checked at model load:
/// `LLMZIP_FORCE_KERNEL={scalar,avx2,neon}`.
pub const FORCE_KERNEL_ENV: &str = "LLMZIP_FORCE_KERNEL";

/// A dispatch tier. All variants exist on every architecture (so config
/// files and CLI flags parse everywhere); availability is checked by
/// [`KernelTier::available`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelTier {
    /// Portable fallback — also the *specification* the vector tiers
    /// must match bit for bit.
    Scalar,
    /// x86_64 AVX2 (256-bit f32, `pmaddwd`-based i8).
    Avx2,
    /// aarch64 NEON (128-bit f32 pairs, `smull`-based i8).
    Neon,
}

impl KernelTier {
    /// Best tier supported by the running CPU.
    pub fn detect() -> KernelTier {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return KernelTier::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return KernelTier::Neon;
            }
        }
        KernelTier::Scalar
    }

    /// Whether this tier can run on the current CPU.
    pub fn available(self) -> bool {
        match self {
            KernelTier::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelTier::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            KernelTier::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    pub fn parse(s: &str) -> Result<KernelTier> {
        Ok(match s {
            "scalar" => KernelTier::Scalar,
            "avx2" => KernelTier::Avx2,
            "neon" => KernelTier::Neon,
            other => bail!("unknown kernel tier '{other}' (expected scalar|avx2|neon)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Neon => "neon",
        }
    }

    /// Tier used when none is requested explicitly: the
    /// [`FORCE_KERNEL_ENV`] override if set (an error if it names a tier
    /// this CPU cannot run), else [`KernelTier::detect`].
    pub fn resolve() -> Result<KernelTier> {
        // The override is read once at model load and tiers are bit-identical,
        // so this environment read can never change container bytes (the
        // tier-equivalence tests pin this).
        // lint: allow(L4) load-time tier override; tiers are bit-identical
        match std::env::var(FORCE_KERNEL_ENV) {
            Ok(v) if !v.is_empty() => {
                let tier = KernelTier::parse(&v)?;
                if !tier.available() {
                    bail!("{FORCE_KERNEL_ENV}={v} but this CPU does not support it");
                }
                Ok(tier)
            }
            _ => Ok(KernelTier::detect()),
        }
    }
}

/// Kernel configuration resolved once at model load.
#[derive(Clone, Copy, Debug)]
pub struct KernelOptions {
    /// Explicit tier; `None` resolves via [`KernelTier::resolve`]
    /// (environment override, then CPU detection). Tests use the
    /// explicit form — mutating the environment races under the
    /// parallel test harness.
    pub tier: Option<KernelTier>,
    /// Build the interleaved panel weight copies (roughly doubles
    /// resident weight memory; disable on memory-constrained hosts —
    /// output bytes are identical either way, matmuls just run at
    /// scalar-stride speed without panels).
    pub panels: bool,
}

impl Default for KernelOptions {
    fn default() -> Self {
        KernelOptions { tier: None, panels: true }
    }
}

/// Interleaved-panel copy of a row-major `[d_in, d_out]` f32 weight.
///
/// `d_in` is padded to a multiple of [`F32_LANES`] with zero rows and
/// `d_out` to a multiple of [`F32_PANEL_COLS`] with zero columns; source
/// element `w[i * d_out + j]` lands at
/// `data[(j / 4) * 4 * d_in_pad + (i / 8) * 32 + (j % 4) * 8 + i % 8]`.
#[derive(Clone, Debug)]
pub struct PanelF32 {
    pub d_in: usize,
    pub d_out: usize,
    /// `d_in` rounded up to a multiple of [`F32_LANES`].
    pub d_in_pad: usize,
    pub data: Vec<f32>,
}

impl PanelF32 {
    /// Deterministic layout transform; `w` is the unchanged row-major
    /// `.lmz` tensor data.
    pub fn build(w: &[f32], d_in: usize, d_out: usize) -> PanelF32 {
        assert_eq!(w.len(), d_in * d_out, "panel shape mismatch");
        let d_in_pad = d_in.div_ceil(F32_LANES) * F32_LANES;
        let n_panels = d_out.div_ceil(F32_PANEL_COLS);
        let mut data = vec![0.0f32; n_panels * F32_PANEL_COLS * d_in_pad];
        for p in 0..n_panels {
            let base = p * F32_PANEL_COLS * d_in_pad;
            for r in 0..F32_PANEL_COLS {
                let j = p * F32_PANEL_COLS + r;
                if j >= d_out {
                    break;
                }
                for i in 0..d_in {
                    let (k, jj) = (i / F32_LANES, i % F32_LANES);
                    data[base + k * F32_LANES * F32_PANEL_COLS + r * F32_LANES + jj] =
                        w[i * d_out + j];
                }
            }
        }
        PanelF32 { d_in, d_out, d_in_pad, data }
    }

    pub fn resident_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Transposed copy of a row-major `[d_in, d_out]` i8 weight: one
/// contiguous row per output column, `d_in` zero-padded to a multiple of
/// [`I8_LANES`]. Source element `wq[i * d_out + j]` lands at
/// `data[j * d_in_pad + i]`.
#[derive(Clone, Debug)]
pub struct PanelI8 {
    pub d_in: usize,
    pub d_out: usize,
    /// `d_in` rounded up to a multiple of [`I8_LANES`].
    pub d_in_pad: usize,
    pub data: Vec<i8>,
}

impl PanelI8 {
    pub fn build(wq: &[i8], d_in: usize, d_out: usize) -> PanelI8 {
        assert_eq!(wq.len(), d_in * d_out, "panel shape mismatch");
        let d_in_pad = d_in.div_ceil(I8_LANES) * I8_LANES;
        let mut data = vec![0i8; d_out * d_in_pad];
        for j in 0..d_out {
            for i in 0..d_in {
                data[j * d_in_pad + i] = wq[i * d_out + j];
            }
        }
        PanelI8 { d_in, d_out, d_in_pad, data }
    }

    pub fn resident_bytes(&self) -> usize {
        self.data.len()
    }
}

/// A panelized weight copy, matching the source tensor's dtype.
#[derive(Clone, Debug)]
pub enum Panels {
    F32(PanelF32),
    I8(PanelI8),
}

impl Panels {
    pub fn resident_bytes(&self) -> usize {
        match self {
            Panels::F32(p) => p.resident_bytes(),
            Panels::I8(p) => p.resident_bytes(),
        }
    }

    pub fn as_f32(&self) -> Option<&PanelF32> {
        match self {
            Panels::F32(p) => Some(p),
            Panels::I8(_) => None,
        }
    }

    pub fn as_i8(&self) -> Option<&PanelI8> {
        match self {
            Panels::I8(p) => Some(p),
            Panels::F32(_) => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch entry points. `tier` must satisfy `tier.available()` — the
// `ResolvedPlan` constructor guarantees this, and the vector arms are
// compiled only for their architecture, so an unavailable foreign tier
// falls through to scalar rather than faulting.
// ---------------------------------------------------------------------------

/// Fixed-tree f32 dot product of two contiguous equal-length slices.
#[inline]
pub fn dot_f32(tier: KernelTier, a: &[f32], b: &[f32]) -> f32 {
    debug_assert!(tier.available());
    match tier {
        // SAFETY: tier is Avx2 only after `available()` saw AVX2 at resolve.
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { avx2::dot_f32(a, b) },
        // SAFETY: tier is Neon only after `available()` saw NEON at resolve.
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::dot_f32(a, b) },
        _ => scalar::dot_f32(a, b),
    }
}

/// Exact i8×i8 dot with i32 accumulation (order-free; any tier returns
/// the identical i32).
#[inline]
pub fn dot_i8(tier: KernelTier, a: &[i8], b: &[i8]) -> i32 {
    debug_assert!(tier.available());
    match tier {
        // SAFETY: tier is Avx2 only after `available()` saw AVX2 at resolve.
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { avx2::dot_i8(a, b) },
        // SAFETY: tier is Neon only after `available()` saw NEON at resolve.
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::dot_i8(a, b) },
        _ => scalar::dot_i8(a, b),
    }
}

/// `y[i] += a * x[i]` — element-wise, so lane width cannot change the
/// per-element operation order and every tier is bit-identical.
#[inline]
pub fn axpy_f32(tier: KernelTier, a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert!(tier.available());
    match tier {
        // SAFETY: tier is Avx2 only after `available()` saw AVX2 at resolve.
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { avx2::axpy_f32(a, x, y) },
        // SAFETY: tier is Neon only after `available()` saw NEON at resolve.
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::axpy_f32(a, x, y) },
        _ => scalar::axpy_f32(a, x, y),
    }
}

/// Per-lane symmetric i8 quantization: `sx[l] = maxabs / 127`, `qx[l*d
/// ..] = round(x / sx)` (half away from zero), all-zero lanes get
/// `sx = 0` and zeroed codes. Bit-identical across tiers: maxabs is a
/// pure lane-wise `max` (order-free on the non-negative `|x|` values)
/// and rounding uses the shared `trunc(t + copysign(0.5, t))` formula in
/// every tier.
#[inline]
pub fn quantize_lanes(tier: KernelTier, n: usize, d: usize, xs: &[f32], qx: &mut [i8], sx: &mut [f32]) {
    debug_assert!(tier.available());
    match tier {
        // SAFETY: tier is Avx2 only after `available()` saw AVX2 at resolve.
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { avx2::quantize_lanes(n, d, xs, qx, sx) },
        // SAFETY: tier is Neon only after `available()` saw NEON at resolve.
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::quantize_lanes(n, d, xs, qx, sx) },
        _ => scalar::quantize_lanes(n, d, xs, qx, sx),
    }
}

/// `ys[l*d_out + j] += Σ_i xs[l*d_in + i] * w[i*d_out + j]` for `n`
/// lanes, every per-output sum in the fixed tree order. With a panel the
/// vector tiers stream contiguous memory; without one (panels disabled)
/// all tiers fall back to the scalar strided-tree walk — same bits,
/// scalar speed.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn matmul_f32(
    tier: KernelTier,
    n: usize,
    d_in: usize,
    d_out: usize,
    xs: &[f32],
    w: &[f32],
    panel: Option<&PanelF32>,
    ys: &mut [f32],
) {
    debug_assert!(tier.available());
    let Some(p) = panel else {
        scalar::matmul_f32_cols(n, d_in, d_out, xs, w, ys);
        return;
    };
    debug_assert!(p.d_in == d_in && p.d_out == d_out);
    match tier {
        // SAFETY: tier is Avx2 only after `available()` saw AVX2 at resolve.
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { avx2::matmul_f32_panel(n, d_in, d_out, xs, p, ys) },
        // SAFETY: tier is Neon only after `available()` saw NEON at resolve.
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::matmul_f32_panel(n, d_in, d_out, xs, p, ys) },
        _ => scalar::matmul_f32_panel(n, d_in, d_out, xs, p, ys),
    }
}

/// Quantized matmul over prequantized activations:
/// `ys[l*d_out + j] += sx[l] * ws[j] * Σ_i qx[l*d_in + i] * wq[i*d_out + j]`.
/// The inner sum is exact i32, so the panel dot kernels and the
/// row-major axpy fallback (used when panels are disabled) produce
/// identical bytes on every tier. `acc` is `n * d_out` i32 scratch for
/// the fallback. Lanes with `sx[l] == 0` are skipped entirely.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn matmul_i8(
    tier: KernelTier,
    n: usize,
    d_in: usize,
    d_out: usize,
    wq: &[i8],
    ws: &[f32],
    panel: Option<&PanelI8>,
    qx: &[i8],
    sx: &[f32],
    acc: &mut [i32],
    ys: &mut [f32],
) {
    debug_assert!(tier.available());
    let Some(p) = panel else {
        scalar::matmul_i8_axpy(n, d_in, d_out, wq, ws, qx, sx, acc, ys);
        return;
    };
    debug_assert!(p.d_in == d_in && p.d_out == d_out);
    match tier {
        // SAFETY: tier is Avx2 only after `available()` saw AVX2 at resolve.
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { avx2::matmul_i8_panel(n, d_in, d_out, p, ws, qx, sx, ys) },
        // SAFETY: tier is Neon only after `available()` saw NEON at resolve.
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => unsafe { neon::matmul_i8_panel(n, d_in, d_out, p, ws, qx, sx, ys) },
        _ => scalar::matmul_i8_panel(n, d_in, d_out, p, ws, qx, sx, ys),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_parse_roundtrip() {
        for t in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Neon] {
            assert_eq!(KernelTier::parse(t.as_str()).unwrap(), t);
        }
        assert!(KernelTier::parse("sse9").is_err());
    }

    #[test]
    fn detected_tier_is_available() {
        let t = KernelTier::detect();
        assert!(t.available());
        assert!(KernelTier::Scalar.available());
    }

    #[test]
    fn panel_f32_layout_maps_lmz_bytes() {
        // 3x5 row-major source; check the documented index map, the zero
        // padding, and the sizes.
        let (d_in, d_out) = (3usize, 5usize);
        let w: Vec<f32> = (0..d_in * d_out).map(|v| v as f32 + 1.0).collect();
        let p = PanelF32::build(&w, d_in, d_out);
        assert_eq!(p.d_in_pad, F32_LANES);
        assert_eq!(p.data.len(), 2 * F32_PANEL_COLS * F32_LANES);
        for i in 0..d_in {
            for j in 0..d_out {
                let idx = (j / F32_PANEL_COLS) * F32_PANEL_COLS * p.d_in_pad
                    + (i / F32_LANES) * F32_LANES * F32_PANEL_COLS
                    + (j % F32_PANEL_COLS) * F32_LANES
                    + i % F32_LANES;
                assert_eq!(p.data[idx], w[i * d_out + j]);
            }
        }
        // Everything not covered by the map is zero padding.
        let live: f64 = w.iter().map(|&v| v as f64).sum();
        let total: f64 = p.data.iter().map(|&v| v as f64).sum();
        assert_eq!(live, total);
    }

    #[test]
    fn panel_i8_layout_is_transposed_rows() {
        let (d_in, d_out) = (5usize, 3usize);
        let wq: Vec<i8> = (0..d_in * d_out).map(|v| v as i8 - 7).collect();
        let p = PanelI8::build(&wq, d_in, d_out);
        assert_eq!(p.d_in_pad, I8_LANES);
        assert_eq!(p.data.len(), d_out * I8_LANES);
        for i in 0..d_in {
            for j in 0..d_out {
                assert_eq!(p.data[j * p.d_in_pad + i], wq[i * d_out + j]);
            }
            for j in 0..d_out {
                assert!(p.data[j * p.d_in_pad + d_in..(j + 1) * p.d_in_pad].iter().all(|&v| v == 0));
            }
        }
    }
}
