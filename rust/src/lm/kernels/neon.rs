//! NEON kernels (aarch64). Bit-identical to `scalar` by construction.
//!
//! The 8 virtual f32 lanes live in **two** `float32x4_t` accumulators
//! (lanes 0–3 and 4–7); `vaddq_f32(lo, hi)` produces exactly the
//! `s_k = l_k + l_{k+4}` vector of the canonical combine, and the final
//! `(s0+s2) + (s1+s3)` is done with scalar lane extracts — NOT
//! `vaddvq_f32`, whose `faddp`-pair order `(s0+s1) + (s2+s3)` would
//! change the bits.
//!
//! **No FMA**: every multiply-accumulate is `vaddq_f32(acc,
//! vmulq_f32(a, b))`, never `vmlaq_f32`/`vfmaq_f32` (those emit fused
//! FMLA, which skips the intermediate rounding the scalar spec
//! performs).
//!
//! i8 dots: `vmull_s8` widens products to i16 (each ≤ 127², exact),
//! `vpadalq_s16` pairwise-accumulates into i32 lanes, `vaddvq_s32`
//! folds — all integer, all exact, order-free.
//!
//! Value-only intrinsics are safe inside these `#[target_feature]`
//! bodies; the explicit `unsafe` blocks mark exactly the pointer
//! loads/stores, each with the bound that keeps it in-range.

use super::{PanelF32, PanelI8, F32_LANES, F32_PANEL_COLS, I8_LANES};
use core::arch::aarch64::*;

/// Canonical tree combine from the two half-accumulators. Value-only
/// (no memory access), so it is a safe `#[target_feature]` fn:
/// callable without `unsafe` from the NEON kernels, never from generic
/// code.
#[inline]
#[target_feature(enable = "neon")]
fn combine2q(lo: float32x4_t, hi: float32x4_t) -> f32 {
    let s = vaddq_f32(lo, hi); // s_k = l_k + l_{k+4}
    let s0 = vgetq_lane_f32(s, 0);
    let s1 = vgetq_lane_f32(s, 1);
    let s2 = vgetq_lane_f32(s, 2);
    let s3 = vgetq_lane_f32(s, 3);
    (s0 + s2) + (s1 + s3)
}

/// # Safety
/// Requires NEON (checked once at model load); `a.len() == b.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc_lo = vdupq_n_f32(0.0);
    let mut acc_hi = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + F32_LANES <= n {
        // SAFETY: i + F32_LANES <= n and both slices hold n elements,
        // so the four 4-lane loads (offsets i and i + 4) are in bounds.
        let (a_lo, a_hi, b_lo, b_hi) = unsafe {
            (
                vld1q_f32(a.as_ptr().add(i)),
                vld1q_f32(a.as_ptr().add(i + 4)),
                vld1q_f32(b.as_ptr().add(i)),
                vld1q_f32(b.as_ptr().add(i + 4)),
            )
        };
        acc_lo = vaddq_f32(acc_lo, vmulq_f32(a_lo, b_lo));
        acc_hi = vaddq_f32(acc_hi, vmulq_f32(a_hi, b_hi));
        i += F32_LANES;
    }
    if i < n {
        let mut ta = [0.0f32; F32_LANES];
        let mut tb = [0.0f32; F32_LANES];
        ta[..n - i].copy_from_slice(&a[i..]);
        tb[..n - i].copy_from_slice(&b[i..]);
        // SAFETY: ta/tb are exactly F32_LANES-wide stack arrays, so
        // loads at offsets 0 and 4 are in bounds.
        let (ta_lo, ta_hi, tb_lo, tb_hi) = unsafe {
            (
                vld1q_f32(ta.as_ptr()),
                vld1q_f32(ta.as_ptr().add(4)),
                vld1q_f32(tb.as_ptr()),
                vld1q_f32(tb.as_ptr().add(4)),
            )
        };
        acc_lo = vaddq_f32(acc_lo, vmulq_f32(ta_lo, tb_lo));
        acc_hi = vaddq_f32(acc_hi, vmulq_f32(ta_hi, tb_hi));
    }
    combine2q(acc_lo, acc_hi)
}

/// # Safety
/// Requires NEON (checked once at model load); slice geometry per
/// `super::matmul_f32` (xs is n×d_in, ys is n×d_out, p packs d_out
/// columns of d_in_pad-padded weights).
#[target_feature(enable = "neon")]
pub unsafe fn matmul_f32_panel(
    n: usize,
    d_in: usize,
    d_out: usize,
    xs: &[f32],
    p: &PanelF32,
    ys: &mut [f32],
) {
    let full = d_in / F32_LANES;
    let rem = d_in % F32_LANES;
    let n_panels = p.data.len() / (F32_PANEL_COLS * p.d_in_pad);
    for l in 0..n {
        let x = &xs[l * d_in..(l + 1) * d_in];
        let mut xt = [0.0f32; F32_LANES];
        if rem > 0 {
            xt[..rem].copy_from_slice(&x[full * F32_LANES..]);
        }
        let y = &mut ys[l * d_out..(l + 1) * d_out];
        for pi in 0..n_panels {
            // SAFETY: panel pi spans F32_PANEL_COLS * d_in_pad floats of
            // p.data (pi < n_panels bounds it); group offsets step by
            // F32_LANES * F32_PANEL_COLS up to d_in_pad, and each column
            // load reads F32_LANES floats inside the group. x loads
            // cover k * F32_LANES + 8 <= d_in; the tail reads the
            // F32_LANES-wide zero-padded xt instead of x.
            let acc = unsafe {
                let base = p.data.as_ptr().add(pi * F32_PANEL_COLS * p.d_in_pad);
                // One (lo, hi) accumulator pair per interleaved output.
                let mut acc = [vdupq_n_f32(0.0); 8];
                for k in 0..full {
                    let x_lo = vld1q_f32(x.as_ptr().add(k * F32_LANES));
                    let x_hi = vld1q_f32(x.as_ptr().add(k * F32_LANES + 4));
                    let g = base.add(k * F32_LANES * F32_PANEL_COLS);
                    for r in 0..F32_PANEL_COLS {
                        let w_lo = vld1q_f32(g.add(r * F32_LANES));
                        let w_hi = vld1q_f32(g.add(r * F32_LANES + 4));
                        acc[2 * r] = vaddq_f32(acc[2 * r], vmulq_f32(x_lo, w_lo));
                        acc[2 * r + 1] = vaddq_f32(acc[2 * r + 1], vmulq_f32(x_hi, w_hi));
                    }
                }
                if rem > 0 {
                    let x_lo = vld1q_f32(xt.as_ptr());
                    let x_hi = vld1q_f32(xt.as_ptr().add(4));
                    let g = base.add(full * F32_LANES * F32_PANEL_COLS);
                    for r in 0..F32_PANEL_COLS {
                        let w_lo = vld1q_f32(g.add(r * F32_LANES));
                        let w_hi = vld1q_f32(g.add(r * F32_LANES + 4));
                        acc[2 * r] = vaddq_f32(acc[2 * r], vmulq_f32(x_lo, w_lo));
                        acc[2 * r + 1] = vaddq_f32(acc[2 * r + 1], vmulq_f32(x_hi, w_hi));
                    }
                }
                acc
            };
            let j0 = pi * F32_PANEL_COLS;
            let live = F32_PANEL_COLS.min(d_out - j0);
            for r in 0..live {
                y[j0 + r] += combine2q(acc[2 * r], acc[2 * r + 1]);
            }
        }
    }
}

/// Exact i8×i8 dot over one zero-padded block pair. Value-only, safe to
/// call from NEON contexts (see `combine2q`).
#[inline]
#[target_feature(enable = "neon")]
fn mac_i8(acc: int32x4_t, va: int8x16_t, vb: int8x16_t) -> int32x4_t {
    let lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb));
    let hi = vmull_s8(vget_high_s8(va), vget_high_s8(vb));
    vpadalq_s16(vpadalq_s16(acc, lo), hi)
}

/// # Safety
/// Requires NEON (checked once at model load); `a.len() == b.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let full = n / I8_LANES;
    let rem = n % I8_LANES;
    let mut acc = vdupq_n_s32(0);
    for k in 0..full {
        // SAFETY: (k + 1) * I8_LANES <= n and both slices hold n bytes,
        // so each 16-byte load is in bounds.
        let (va, vb) = unsafe {
            (vld1q_s8(a.as_ptr().add(k * I8_LANES)), vld1q_s8(b.as_ptr().add(k * I8_LANES)))
        };
        acc = mac_i8(acc, va, vb);
    }
    if rem > 0 {
        let mut ta = [0i8; I8_LANES];
        let mut tb = [0i8; I8_LANES];
        ta[..rem].copy_from_slice(&a[full * I8_LANES..]);
        tb[..rem].copy_from_slice(&b[full * I8_LANES..]);
        // SAFETY: ta/tb are exactly I8_LANES (16) bytes on the stack.
        let (va, vb) = unsafe { (vld1q_s8(ta.as_ptr()), vld1q_s8(tb.as_ptr())) };
        acc = mac_i8(acc, va, vb);
    }
    vaddvq_s32(acc)
}

/// # Safety
/// Requires NEON (checked once at model load); slice geometry per
/// `super::matmul_i8` (qx is n×d_in, ys is n×d_out, p rows are
/// d_in_pad-padded and zero-filled past d_in).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn matmul_i8_panel(
    n: usize,
    d_in: usize,
    d_out: usize,
    p: &PanelI8,
    ws: &[f32],
    qx: &[i8],
    sx: &[f32],
    ys: &mut [f32],
) {
    let full = d_in / I8_LANES;
    let rem = d_in % I8_LANES;
    for l in 0..n {
        let s = sx[l];
        if s == 0.0 {
            continue;
        }
        let q = &qx[l * d_in..(l + 1) * d_in];
        let mut qt = [0i8; I8_LANES];
        if rem > 0 {
            qt[..rem].copy_from_slice(&q[full * I8_LANES..]);
        }
        let y = &mut ys[l * d_out..(l + 1) * d_out];
        for j in 0..d_out {
            // SAFETY: row j spans d_in_pad bytes of p.data (j < d_out
            // rows are packed back to back); k * I8_LANES + 16 <=
            // d_in <= d_in_pad bounds the weight and activation loads.
            // The tail loads the 16-byte zero-padded qt, and the weight
            // row is zero-filled past d_in, so its full-width tail load
            // is in-bounds and exact.
            let acc = unsafe {
                let row = p.data.as_ptr().add(j * p.d_in_pad);
                let mut acc = vdupq_n_s32(0);
                for k in 0..full {
                    acc = mac_i8(
                        acc,
                        vld1q_s8(q.as_ptr().add(k * I8_LANES)),
                        vld1q_s8(row.add(k * I8_LANES)),
                    );
                }
                if rem > 0 {
                    acc = mac_i8(acc, vld1q_s8(qt.as_ptr()), vld1q_s8(row.add(full * I8_LANES)));
                }
                acc
            };
            y[j] += s * ws[j] * vaddvq_s32(acc) as f32;
        }
    }
}

/// # Safety
/// Requires NEON (checked once at model load); `x.len() == y.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let va = vdupq_n_f32(a);
    let mut i = 0;
    while i + 4 <= n {
        // SAFETY: i + 4 <= n == x.len() == y.len() bounds both loads
        // and the store.
        unsafe {
            let xv = vld1q_f32(x.as_ptr().add(i));
            let yv = vld1q_f32(y.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(yv, vmulq_f32(va, xv)));
        }
        i += 4;
    }
    while i < n {
        y[i] += a * x[i];
        i += 1;
    }
}

/// Quantize four activations: `clamp(trunc(t + copysign(0.5, t)))`.
/// `vcvtq_s32_f32` truncates toward zero, matching
/// `scalar::quantize_one` (round(t) == trunc(t + copysign(0.5, t)) for
/// the in-domain |t| ≤ 127).
///
/// # Safety
/// `ptr` must be valid for reading four f32 values.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn quant4(ptr: *const f32, inv: f32) -> int32x4_t {
    let sign = vdupq_n_u32(0x8000_0000);
    let half_bits = vdupq_n_u32(0x3F00_0000); // +0.5f32
    // SAFETY: caller guarantees ptr is readable for four f32s.
    let t = vmulq_n_f32(unsafe { vld1q_f32(ptr) }, inv);
    let tb = vreinterpretq_u32_f32(t);
    let half = vreinterpretq_f32_u32(vorrq_u32(vandq_u32(tb, sign), half_bits));
    let r = vcvtq_s32_f32(vaddq_f32(t, half));
    vminq_s32(vmaxq_s32(r, vdupq_n_s32(-127)), vdupq_n_s32(127))
}

/// # Safety
/// Requires NEON (checked once at model load); `xs` is n×d, `qx` is
/// n×d, `sx` holds n scales.
#[target_feature(enable = "neon")]
pub unsafe fn quantize_lanes(n: usize, d: usize, xs: &[f32], qx: &mut [i8], sx: &mut [f32]) {
    for l in 0..n {
        let row = &xs[l * d..(l + 1) * d];
        let mut vm = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= d {
            // SAFETY: i + 4 <= d == row.len() bounds the load.
            let v = unsafe { vld1q_f32(row.as_ptr().add(i)) };
            vm = vmaxq_f32(vm, vabsq_f32(v));
            i += 4;
        }
        let mut maxabs = vmaxvq_f32(vm);
        for &v in &row[i..] {
            maxabs = maxabs.max(v.abs());
        }

        let q = &mut qx[l * d..(l + 1) * d];
        if maxabs == 0.0 {
            sx[l] = 0.0;
            q.fill(0);
            continue;
        }
        let scale = maxabs / 127.0;
        sx[l] = scale;
        let inv = 1.0 / scale;

        let mut i = 0;
        while i + F32_LANES <= d {
            // SAFETY: i + F32_LANES <= d == row.len(), so quant4 reads
            // rows [i, i + 4) and [i + 4, i + 8) in bounds; `out` is an
            // 8-byte stack array for the store.
            unsafe {
                let c_lo = quant4(row.as_ptr().add(i), inv);
                let c_hi = quant4(row.as_ptr().add(i + 4), inv);
                let p16 = vcombine_s16(vqmovn_s32(c_lo), vqmovn_s32(c_hi));
                let p8 = vqmovn_s16(p16);
                let mut out = [0i8; 8];
                vst1_s8(out.as_mut_ptr(), p8);
                q[i..i + F32_LANES].copy_from_slice(&out);
            }
            i += F32_LANES;
        }
        for (qi, &v) in q[i..].iter_mut().zip(&row[i..]) {
            *qi = super::scalar::quantize_one(v, inv);
        }
    }
}
