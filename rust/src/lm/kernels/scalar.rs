//! Portable kernels — the bit-exact *specification* the vector tiers
//! reproduce.
//!
//! Every f32 reduction here is the fixed-tree order: accumulate into
//! [`F32_LANES`] virtual lanes (element `i` into lane `i % 8`; a
//! remainder of `r` elements touches lanes `0..r`, exactly as if the
//! input were zero-padded), then combine as
//! `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` — the natural AVX2
//! `extractf128/movehl/shuffle` horizontal add. The vector tiers perform
//! the same adds on the same values in the same order, just in fewer
//! instructions.

use super::{PanelF32, PanelI8, F32_LANES, F32_PANEL_COLS};

/// The canonical 8-lane combine. `s = vaddq(acc_lo, acc_hi)` /
/// `_mm_add_ps(cast128, extract128)` leaves `s[k] = l_k + l_{k+4}`; the
/// final two adds mirror `movehl` + `shuffle(1)`.
#[inline(always)]
pub fn combine8(lanes: &[f32; F32_LANES]) -> f32 {
    let s0 = lanes[0] + lanes[4];
    let s1 = lanes[1] + lanes[5];
    let s2 = lanes[2] + lanes[6];
    let s3 = lanes[3] + lanes[7];
    (s0 + s2) + (s1 + s3)
}

/// Fixed-tree dot of two contiguous slices.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; F32_LANES];
    let mut i = 0;
    while i + F32_LANES <= a.len() {
        for j in 0..F32_LANES {
            lanes[j] += a[i + j] * b[i + j];
        }
        i += F32_LANES;
    }
    for j in 0..a.len() - i {
        lanes[j] += a[i + j] * b[i + j];
    }
    combine8(&lanes)
}

/// Fixed-tree dot of `x` against column `col` of a row-major
/// `[d_in, d_out]` matrix (stride `d_out`). Same tree as [`dot_f32`] —
/// only the addressing differs.
#[inline]
pub fn dot_f32_col(x: &[f32], w: &[f32], col: usize, d_out: usize) -> f32 {
    let mut lanes = [0.0f32; F32_LANES];
    let mut i = 0;
    while i + F32_LANES <= x.len() {
        for j in 0..F32_LANES {
            lanes[j] += x[i + j] * w[(i + j) * d_out + col];
        }
        i += F32_LANES;
    }
    for j in 0..x.len() - i {
        lanes[j] += x[i + j] * w[(i + j) * d_out + col];
    }
    combine8(&lanes)
}

/// No-panel f32 matmul: per-output strided tree walk. Used by every tier
/// when panels are disabled — identical bits to the panel kernels,
/// scalar speed.
pub fn matmul_f32_cols(n: usize, d_in: usize, d_out: usize, xs: &[f32], w: &[f32], ys: &mut [f32]) {
    debug_assert_eq!(w.len(), d_in * d_out);
    for l in 0..n {
        let x = &xs[l * d_in..(l + 1) * d_in];
        let y = &mut ys[l * d_out..(l + 1) * d_out];
        for (j, yj) in y.iter_mut().enumerate() {
            *yj += dot_f32_col(x, w, j, d_out);
        }
    }
}

/// Panel f32 matmul, scalar tier. Walks the interleaved panel exactly
/// like the vector kernels but one element at a time; the padded rows
/// beyond `d_in` contribute `x_pad * 0.0` terms that cannot change the
/// accumulator bits, so this loop simply stops at `d_in`.
pub fn matmul_f32_panel(n: usize, d_in: usize, d_out: usize, xs: &[f32], p: &PanelF32, ys: &mut [f32]) {
    let full = d_in / F32_LANES;
    let rem = d_in % F32_LANES;
    let n_panels = p.data.len() / (F32_PANEL_COLS * p.d_in_pad);
    for l in 0..n {
        let x = &xs[l * d_in..(l + 1) * d_in];
        let y = &mut ys[l * d_out..(l + 1) * d_out];
        for pi in 0..n_panels {
            let base = pi * F32_PANEL_COLS * p.d_in_pad;
            for r in 0..F32_PANEL_COLS {
                let j = pi * F32_PANEL_COLS + r;
                if j >= d_out {
                    break;
                }
                let mut lanes = [0.0f32; F32_LANES];
                for k in 0..full {
                    let g = base + k * F32_LANES * F32_PANEL_COLS + r * F32_LANES;
                    for jj in 0..F32_LANES {
                        lanes[jj] += x[k * F32_LANES + jj] * p.data[g + jj];
                    }
                }
                if rem > 0 {
                    let g = base + full * F32_LANES * F32_PANEL_COLS + r * F32_LANES;
                    for jj in 0..rem {
                        lanes[jj] += x[full * F32_LANES + jj] * p.data[g + jj];
                    }
                }
                y[j] += combine8(&lanes);
            }
        }
    }
}

/// Exact i8×i8 dot. `a.len() * 127 * 127` fits i32 with orders of
/// magnitude to spare for every model width, so accumulation order is
/// irrelevant.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    for i in 0..a.len() {
        acc += a[i] as i32 * b[i] as i32;
    }
    acc
}

/// No-panel i8 matmul: the seed engine's row-major axpy walk over the
/// unchanged `.lmz` layout (skipping zero codes), kept as the fallback
/// when panels are disabled. The i32 accumulators are exact, so this
/// produces the same bytes as the panel dot kernels on any tier.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i8_axpy(
    n: usize,
    d_in: usize,
    d_out: usize,
    wq: &[i8],
    ws: &[f32],
    qx: &[i8],
    sx: &[f32],
    acc: &mut [i32],
    ys: &mut [f32],
) {
    debug_assert_eq!(wq.len(), d_in * d_out);
    let acc = &mut acc[..n * d_out];
    acc.fill(0);
    for l in 0..n {
        if sx[l] == 0.0 {
            continue;
        }
        let q = &qx[l * d_in..(l + 1) * d_in];
        let a = &mut acc[l * d_out..(l + 1) * d_out];
        for (i, &qi) in q.iter().enumerate() {
            if qi == 0 {
                continue;
            }
            let xi = qi as i32;
            let row = &wq[i * d_out..(i + 1) * d_out];
            for (aj, &rj) in a.iter_mut().zip(row) {
                *aj += xi * rj as i32;
            }
        }
    }
    for l in 0..n {
        let s = sx[l];
        if s == 0.0 {
            continue;
        }
        let a = &acc[l * d_out..(l + 1) * d_out];
        let y = &mut ys[l * d_out..(l + 1) * d_out];
        for j in 0..d_out {
            y[j] += s * ws[j] * a[j] as f32;
        }
    }
}

/// Panel i8 matmul, scalar tier: contiguous per-output dot over the
/// transposed rows.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i8_panel(
    n: usize,
    d_in: usize,
    d_out: usize,
    p: &PanelI8,
    ws: &[f32],
    qx: &[i8],
    sx: &[f32],
    ys: &mut [f32],
) {
    for l in 0..n {
        let s = sx[l];
        if s == 0.0 {
            continue;
        }
        let q = &qx[l * d_in..(l + 1) * d_in];
        let y = &mut ys[l * d_out..(l + 1) * d_out];
        for j in 0..d_out {
            let row = &p.data[j * p.d_in_pad..j * p.d_in_pad + d_in];
            y[j] += s * ws[j] * dot_i8(q, row) as f32;
        }
    }
}

/// `y[i] += a * x[i]`.
#[inline]
pub fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Round-half-away-from-zero as `trunc(t + copysign(0.5, t))` — equal to
/// `f32::round` for every `|t| < 2^22` (here `|t| ≤ ~127.5`, and `t +
/// 0.5` is exact in that range), but expressible with plain vector
/// ops (`or`/`add`/`round-to-zero`) so scalar and vector tiers share the
/// formula verbatim.
#[inline(always)]
pub fn quantize_one(v: f32, inv: f32) -> i8 {
    let t = v * inv;
    let r = (t + 0.5f32.copysign(t)).trunc();
    r.clamp(-127.0, 127.0) as i8
}

/// Per-lane symmetric quantization (see the dispatch wrapper for the
/// contract). Max-abs is order-free: `|x|` values are non-negative, so
/// `max` is a pure selection with no sign-of-zero pitfalls.
pub fn quantize_lanes(n: usize, d: usize, xs: &[f32], qx: &mut [i8], sx: &mut [f32]) {
    for l in 0..n {
        let row = &xs[l * d..(l + 1) * d];
        let mut maxabs = 0.0f32;
        for &v in row {
            maxabs = maxabs.max(v.abs());
        }
        let q = &mut qx[l * d..(l + 1) * d];
        if maxabs == 0.0 {
            sx[l] = 0.0;
            q.fill(0);
            continue;
        }
        let scale = maxabs / 127.0;
        sx[l] = scale;
        let inv = 1.0 / scale;
        for (qi, &v) in q.iter_mut().zip(row) {
            *qi = quantize_one(v, inv);
        }
    }
}
