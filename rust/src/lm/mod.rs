//! The language-model layer on the rust side.
//!
//! * [`config`] — the model registry (must mirror `python/compile/configs.py`).
//! * [`weights`] — typed parameter bundle loaded from `.lmz` files.
//! * [`native`] — a from-scratch rust implementation of the exact same
//!   transformer (matmuls and all). It serves three purposes: a
//!   cross-check on the PJRT numerics, a fallback executor that works
//!   without artifacts, and the reference for unit tests.
//! * [`executor`] — the [`executor::LmExecutor`] trait the compressor and
//!   coordinator program against, with the native implementation here and
//!   the PJRT implementation in [`crate::runtime`].

pub mod config;
pub mod executor;
pub mod native;
pub mod weights;

pub use config::{LmConfig, MAX_CONTEXT, VOCAB};
pub use executor::{ExecutorKind, LmExecutor};
pub use native::NativeExecutor;
pub use weights::Weights;
