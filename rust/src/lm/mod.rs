//! The language-model layer on the rust side.
//!
//! * [`config`] — the model registry (must mirror `python/compile/configs.py`).
//! * [`weights`] — typed parameter bundle loaded from `.lmz` files (v1
//!   all-f32 or v2 dtype-aware with int8-quantized tensors), plus the
//!   [`weights::ResolvedPlan`] that resolves every string-keyed tensor to
//!   a direct index once at model load. [`weights::Precision`] +
//!   [`weights::Weights::fingerprint`] make the exact weight bytes an
//!   explicit contract between compressor and decompressor.
//! * [`native`] — a from-scratch rust implementation of the exact same
//!   transformer. The engine is batched and allocation-free in steady
//!   state: [`native::NativeModel::advance_batch`] pushes all lanes
//!   through each layer together using a preallocated [`native::Scratch`]
//!   arena, and [`native::NativeExecutor`] can partition lanes across a
//!   persistent pool of OS threads (bit-exact for any lane batching or
//!   thread count), with weights shared across replicas via
//!   `Arc<Weights>`. It serves
//!   three purposes: a cross-check on the PJRT numerics, a fallback
//!   executor that works without artifacts, and the reference for unit
//!   tests.
//! * [`kernels`] — the SIMD dispatch layer: one scalar *specification*
//!   per (dtype, op) plus AVX2/NEON tiers that reproduce it bit for bit
//!   (fixed-tree f32 reductions, exact i32 int8 accumulation). The tier
//!   is resolved once at model load ([`kernels::KernelTier::resolve`],
//!   overridable via `LLMZIP_FORCE_KERNEL`) and stored in the
//!   [`weights::ResolvedPlan`] next to the optional interleaved-panel
//!   weight layout the vector matmuls stream from.
//! * [`reference`] — the **frozen seed implementation** (string-keyed
//!   lookups, per-token allocations, pre-PR6 ascending-order reductions).
//!   Never optimized; golden tests pin the modern engine against an
//!   independent fixed-tree re-derivation and bound its drift from this
//!   seed, and the runtime bench reports the speedup against it.
//! * [`executor`] — the [`executor::LmExecutor`] trait the compressor and
//!   coordinator program against: per-lane stepping ([`executor::LmExecutor::step`] /
//!   allocation-free [`executor::LmExecutor::step_into`]) plus the bulk
//!   [`executor::LmExecutor::encode_logits`] encode path with a default
//!   stepping fallback. The native implementation lives here; the PJRT
//!   implementations in [`crate::runtime`].

pub mod config;
pub mod executor;
pub mod kernels;
pub mod native;
pub mod reference;
pub mod weights;

pub use config::{LmConfig, CODED_BYTES, MAX_CONTEXT, VOCAB};
pub use executor::{ExecutorKind, LmExecutor};
pub use kernels::{KernelOptions, KernelTier};
pub use native::{NativeExecutor, Scratch, StepPool};
pub use weights::{Precision, ResolvedPlan, TensorData, TensorView, Weights};
