//! Native rust implementation of the transformer — the same architecture as
//! `python/compile/model.py`, computed with a per-token KV-cache state
//! machine.
//!
//! Crucially, *compression and decompression share this exact code path*
//! (one batched step per token position), so the probability streams on
//! both sides are bit-identical by construction. Numerics agree with the
//! PJRT/XLA executor to ~1e-4 (different reduction orders), which is why
//! containers record which executor produced them.
//!
//! ## Execution architecture (resolved-plan + replica-pool refactor)
//!
//! * **[`crate::lm::weights::ResolvedPlan`]** — every weight tensor is
//!   resolved from its string key to a direct index once at model load;
//!   the hot path never formats, hashes or looks up a name. The plan holds
//!   the bundle behind an `Arc<Weights>`, so every executor replica and
//!   every pool thread reads ONE shared copy of the tensors.
//! * **[`Scratch`]** — a preallocated arena holding every intermediate
//!   buffer (residual stream, norms, q/k/v, attention scores, FF, output
//!   head). Steady-state stepping performs **zero heap allocations**.
//! * **[`NativeModel::advance_batch`]** — processes all lanes through each
//!   layer together, so every weight row is streamed from memory once per
//!   step instead of once per lane. Per-lane accumulation order is
//!   unchanged, so logits are bit-identical to the single-lane path (and
//!   to the frozen seed implementation in [`crate::lm::reference`], which
//!   `tests/golden_logits.rs` asserts).
//! * **[`NativeExecutor`]** — `threads > 1` partitions lanes across a
//!   **persistent worker pool**: long-lived OS threads, each permanently
//!   owning a disjoint lane span and its own `Scratch`, woken per step by
//!   a channel handoff. No `thread::scope` spawn/join anywhere in the
//!   steady-state step path, so even nano-sized models can profit from
//!   threads without paying spawn cost per decoded byte. Bit-exact for any
//!   thread count: lanes are computed independently.
//! * **[`StepPool`] (cross-replica work stealing)** — instead of a private
//!   pool, any number of executors can share ONE [`StepPool`] via
//!   [`NativeExecutor::with_shared_pool`]. A step then fans its disjoint
//!   lane spans into the pool's injector queue; the pool's threads AND the
//!   stepping caller itself pop *whole spans* — their own or a sibling
//!   replica's — until the step's barrier drains. When one replica's batch
//!   underfills the machine, the other replicas' idle step threads pick up
//!   its spans, so the thread budget follows the load instead of the
//!   replica it was spawned for. Lane spans stay disjoint and every lane's
//!   accumulation order is unchanged, so logits remain bit-identical to
//!   the single-threaded path for ANY pool size, replica count, or
//!   stealing schedule (asserted by the tests below and by
//!   `tests/stress_elastic.rs` end-to-end).
//!
//! ## Kernel dispatch (SIMD + dtype)
//!
//! Every hot loop — the projection matmuls, the attention score/value
//! dots, the weight-tied head, and activation quantization — routes
//! through [`crate::lm::kernels`]. A [`crate::lm::kernels::KernelTier`]
//! (scalar / AVX2 / NEON) is resolved once at model load and stored in
//! the [`ResolvedPlan`] along with optional interleaved-panel weight
//! copies; there is exactly one implementation per (dtype, tier) and the
//! engine never re-detects CPU features per call.
//!
//! Per-tensor dtype dispatch is unchanged in spirit: f32 tensors run the
//! fixed-tree f32 kernels, int8-quantized tensors run per-lane dynamic
//! activation quantization + an i8×i8 dot with i32 accumulation + one
//! f32 scale multiply per output element. Activations, norm gains and
//! the KV cache stay f32. The int8 dots are exactly associative and the
//! f32 kernels share one fixed tree-order reduction across every tier
//! (see `lm/kernels`), so logits are bit-identical across lane
//! batchings, thread counts, pool sizes AND dispatch tiers by
//! construction — the lossless-decode requirement. Int8 is still *not*
//! bit-equal to f32, which is why containers record the weight
//! precision and fingerprint (see `compress/llm.rs`).

use crate::lm::config::{LmConfig, MAX_CONTEXT, VOCAB};
use crate::lm::kernels::{self, KernelOptions, KernelTier};
use crate::lm::weights::{ResolvedPlan, TensorView, Weights};
use crate::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// GELU (tanh approximation — matches `jax.nn.gelu(approximate=True)`).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// RMS-norm `x` with `gain` into `out` (no allocation; same reduction
/// order as the seed implementation).
#[inline]
fn rmsnorm_into(x: &[f32], gain: &[f32], out: &mut [f32]) {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = v * inv * g;
    }
}

/// Per-lane incremental state: the KV cache and the current position.
pub struct LaneState {
    /// [layer][kind(k=0,v=1)][pos * d_model ..]
    kv: Vec<f32>,
    pos: usize,
    n_layers: usize,
    d_model: usize,
    max_len: usize,
}

impl LaneState {
    pub fn new(cfg: &LmConfig, max_len: usize) -> Self {
        assert!(max_len <= MAX_CONTEXT);
        LaneState {
            kv: vec![0.0; cfg.n_layers * 2 * max_len * cfg.d_model],
            pos: 0,
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            max_len,
        }
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    #[inline]
    fn kv_slice(&self, layer: usize, kind: usize, pos: usize) -> std::ops::Range<usize> {
        let base = ((layer * 2 + kind) * self.max_len + pos) * self.d_model;
        base..base + self.d_model
    }
}

/// Working memory for the int8 kernels: quantized activations, per-lane
/// activation scales, and the i32 accumulator. Sized for the widest
/// projection (`d_ff`), reused by every dispatch in a step.
struct QuantScratch {
    /// [cap * d_ff] per-lane quantized activations.
    qx: Vec<i8>,
    /// [cap] per-lane activation scales.
    sx: Vec<f32>,
    /// [cap * d_ff] i32 dot-product accumulators.
    acc: Vec<i32>,
}

/// Preallocated working memory for [`NativeModel::advance_batch`], sized
/// once for up to `cap` lanes. Holding one of these per executor (or per
/// worker thread) is what makes steady-state stepping allocation-free.
pub struct Scratch {
    cap: usize,
    /// [cap * d_model] residual stream.
    x: Vec<f32>,
    /// [cap * d_model] rmsnorm output (attn-norm, mlp-norm, final-norm).
    hn: Vec<f32>,
    /// [cap * d_model] each.
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// [cap * d_model] attention output before the wo projection.
    attn: Vec<f32>,
    /// [cap * MAX_CONTEXT] per-lane attention scores.
    scores: Vec<f32>,
    /// [cap * d_ff] feed-forward hidden.
    ff: Vec<f32>,
    /// Int8-dispatch working memory (idle on pure-f32 bundles).
    quant: QuantScratch,
}

impl Scratch {
    pub fn new(cfg: &LmConfig, cap: usize) -> Scratch {
        let d = cfg.d_model;
        let wide = cfg.d_ff().max(d);
        Scratch {
            cap,
            x: vec![0.0; cap * d],
            hn: vec![0.0; cap * d],
            q: vec![0.0; cap * d],
            k: vec![0.0; cap * d],
            v: vec![0.0; cap * d],
            attn: vec![0.0; cap * d],
            scores: vec![0.0; cap * MAX_CONTEXT],
            ff: vec![0.0; cap * cfg.d_ff()],
            quant: QuantScratch {
                qx: vec![0; cap * wide],
                sx: vec![0.0; cap],
                acc: vec![0; cap * wide],
            },
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

/// The model: config + resolved plan (which owns the shared weights),
/// plus precomputed ALiBi slopes.
pub struct NativeModel {
    pub cfg: &'static LmConfig,
    plan: ResolvedPlan,
    slopes: Vec<f32>,
}

impl NativeModel {
    /// Accepts either an owned `Weights` (wrapped into a fresh `Arc`) or an
    /// `Arc<Weights>` already shared with other replicas. Kernel tier and
    /// panel layout resolve to their defaults (environment override or
    /// CPU detection; panels on).
    pub fn new(cfg: &'static LmConfig, weights: impl Into<Arc<Weights>>) -> Self {
        Self::with_opts(cfg, weights, KernelOptions::default())
            .expect("weights were validated against param_spec at load")
    }

    /// [`NativeModel::new`] with explicit kernel options (tests force a
    /// tier programmatically; the serve path threads the panel knob
    /// through here). Errors if an explicitly-requested tier is not
    /// available on this CPU or the environment override is invalid.
    pub fn with_opts(
        cfg: &'static LmConfig,
        weights: impl Into<Arc<Weights>>,
        opts: KernelOptions,
    ) -> Result<Self> {
        let plan = ResolvedPlan::build_with(weights.into(), cfg, opts)?;
        let slopes = (0..cfg.n_heads).map(|h| cfg.alibi_slope(h)).collect();
        Ok(NativeModel { cfg, plan, slopes })
    }

    /// The shared weight bundle (replicas clone this `Arc`, not the data).
    pub fn weights(&self) -> &Arc<Weights> {
        self.plan.weights()
    }

    /// The kernel dispatch tier this model resolved at load.
    pub fn kernel_tier(&self) -> KernelTier {
        self.plan.tier()
    }

    /// Whether this model's matmuls use the panel weight layout.
    pub fn panels_enabled(&self) -> bool {
        self.plan.panels_enabled()
    }

    /// One projection `ys += xs @ tensors[idx]` through the kernel layer:
    /// dtype dispatch on the resolved view, panel lookup from the plan,
    /// tier fixed at load. Int8 tensors quantize `xs` per lane first.
    #[inline]
    fn matmul_idx(
        &self,
        idx: usize,
        n: usize,
        d_in: usize,
        d_out: usize,
        xs: &[f32],
        ys: &mut [f32],
        quant: &mut QuantScratch,
    ) {
        let tier = self.plan.tier();
        match self.plan.view(idx) {
            TensorView::F32(w) => {
                kernels::matmul_f32(tier, n, d_in, d_out, xs, w, self.plan.panel_f32(idx), ys)
            }
            TensorView::I8 { data, scales } => {
                let QuantScratch { qx, sx, acc } = quant;
                kernels::quantize_lanes(tier, n, d_in, xs, qx, sx);
                kernels::matmul_i8(
                    tier,
                    n,
                    d_in,
                    d_out,
                    data,
                    scales,
                    self.plan.panel_i8(idx),
                    qx,
                    sx,
                    acc,
                    ys,
                );
            }
        }
    }

    /// Feed one token per lane; writes each lane's next-token logits into
    /// `out` (`[lanes.len() * VOCAB]` row-major) and advances every lane.
    ///
    /// `head_rows` restricts the weight-tied output head to the first
    /// `head_rows` logit rows (the rest are zeroed): the compressor passes
    /// [`crate::lm::config::CODED_BYTES`] because special tokens are never
    /// range-coded; everything else passes [`VOCAB`]. Values in the
    /// computed rows are bit-identical either way.
    ///
    /// This single routine backs compression, decompression and generation
    /// — bit-exact across all of them (and across lane batchings and
    /// thread counts) by construction.
    pub fn advance_batch(
        &self,
        lanes: &mut [LaneState],
        tokens: &[u32],
        scratch: &mut Scratch,
        out: &mut [f32],
        head_rows: usize,
    ) -> Result<()> {
        let n = lanes.len();
        if tokens.len() != n {
            anyhow::bail!("advance_batch: {} lanes but {} tokens", n, tokens.len());
        }
        if n > scratch.cap {
            anyhow::bail!("advance_batch: {} lanes exceed scratch capacity {}", n, scratch.cap);
        }
        if out.len() != n * VOCAB {
            anyhow::bail!("advance_batch: out buffer {} != {}", out.len(), n * VOCAB);
        }
        if head_rows == 0 || head_rows > VOCAB {
            anyhow::bail!("advance_batch: head_rows {head_rows} out of range 1..={VOCAB}");
        }
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.d_head();
        let ffd = self.cfg.d_ff();
        let tier = self.plan.tier();
        let embed = self.plan.view(self.plan.embed);

        // Token embeddings into the residual stream (int8 embed rows are
        // dequantized with their per-row scale; everything downstream of
        // the lookup is f32 either way).
        for (l, (lane, &tok)) in lanes.iter_mut().zip(tokens.iter()).enumerate() {
            if lane.pos >= lane.max_len {
                anyhow::bail!("lane {l} overflow: pos {} >= max {}", lane.pos, lane.max_len);
            }
            let t = tok as usize;
            if t >= VOCAB {
                anyhow::bail!("lane {l}: token {tok} outside vocabulary");
            }
            let x = &mut scratch.x[l * d..(l + 1) * d];
            match embed {
                TensorView::F32(e) => x.copy_from_slice(&e[t * d..(t + 1) * d]),
                TensorView::I8 { data, scales } => {
                    let s = scales[t];
                    for (xi, &q) in x.iter_mut().zip(&data[t * d..(t + 1) * d]) {
                        *xi = q as f32 * s;
                    }
                }
            }
        }

        let scale = 1.0 / (dh as f32).sqrt();
        for (layer, lp) in self.plan.layers.iter().enumerate() {
            // Norm gains are always f32 (quantize() leaves 1-D tensors
            // alone); the projections dispatch per dtype.
            let attn_norm = self.plan.data(lp.attn_norm);
            let mlp_norm = self.plan.data(lp.mlp_norm);

            for l in 0..n {
                rmsnorm_into(
                    &scratch.x[l * d..(l + 1) * d],
                    attn_norm,
                    &mut scratch.hn[l * d..(l + 1) * d],
                );
            }
            scratch.q[..n * d].fill(0.0);
            scratch.k[..n * d].fill(0.0);
            scratch.v[..n * d].fill(0.0);
            let hn = &scratch.hn[..n * d];
            // The three attention projections consume the same activation
            // buffer: quantize it once and reuse it for every int8 tensor.
            let qkv = [lp.wq, lp.wk, lp.wv];
            if qkv.iter().any(|&i| matches!(self.plan.view(i), TensorView::I8 { .. })) {
                kernels::quantize_lanes(
                    tier,
                    n,
                    d,
                    hn,
                    &mut scratch.quant.qx,
                    &mut scratch.quant.sx,
                );
            }
            for (idx, ys) in [
                (lp.wq, &mut scratch.q[..n * d]),
                (lp.wk, &mut scratch.k[..n * d]),
                (lp.wv, &mut scratch.v[..n * d]),
            ] {
                match self.plan.view(idx) {
                    TensorView::F32(w) => kernels::matmul_f32(
                        tier,
                        n,
                        d,
                        d,
                        hn,
                        w,
                        self.plan.panel_f32(idx),
                        ys,
                    ),
                    TensorView::I8 { data, scales } => {
                        let QuantScratch { qx, sx, acc } = &mut scratch.quant;
                        kernels::matmul_i8(
                            tier,
                            n,
                            d,
                            d,
                            data,
                            scales,
                            self.plan.panel_i8(idx),
                            qx,
                            sx,
                            acc,
                            ys,
                        );
                    }
                }
            }

            // Append k/v to each lane's cache at its current position.
            for (l, lane) in lanes.iter_mut().enumerate() {
                let pos = lane.pos;
                let kr = lane.kv_slice(layer, 0, pos);
                lane.kv[kr].copy_from_slice(&scratch.k[l * d..(l + 1) * d]);
                let vr = lane.kv_slice(layer, 1, pos);
                lane.kv[vr].copy_from_slice(&scratch.v[l * d..(l + 1) * d]);
            }

            // Attention per lane per head over cache positions 0..=pos
            // with ALiBi.
            scratch.attn[..n * d].fill(0.0);
            for (l, lane) in lanes.iter().enumerate() {
                let pos = lane.pos;
                let q_lane = &scratch.q[l * d..(l + 1) * d];
                let attn_out = &mut scratch.attn[l * d..(l + 1) * d];
                let scores =
                    &mut scratch.scores[l * MAX_CONTEXT..l * MAX_CONTEXT + pos + 1];
                for head in 0..h {
                    let slope = self.slopes[head];
                    let qh = &q_lane[head * dh..(head + 1) * dh];
                    let mut max_s = f32::NEG_INFINITY;
                    for (j, sj) in scores.iter_mut().enumerate() {
                        let kj =
                            &lane.kv[lane.kv_slice(layer, 0, j)][head * dh..(head + 1) * dh];
                        let s = kernels::dot_f32(tier, qh, kj) * scale
                            - slope * (pos - j) as f32;
                        max_s = max_s.max(s);
                        *sj = s;
                    }
                    let mut denom = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - max_s).exp();
                        denom += *s;
                    }
                    let inv = 1.0 / denom;
                    let out_h = &mut attn_out[head * dh..(head + 1) * dh];
                    for (j, &w) in scores.iter().enumerate() {
                        let vj =
                            &lane.kv[lane.kv_slice(layer, 1, j)][head * dh..(head + 1) * dh];
                        kernels::axpy_f32(tier, w * inv, vj, out_h);
                    }
                }
            }
            let attn = &scratch.attn[..n * d];
            self.matmul_idx(lp.wo, n, d, d, attn, &mut scratch.x[..n * d], &mut scratch.quant);

            for l in 0..n {
                rmsnorm_into(
                    &scratch.x[l * d..(l + 1) * d],
                    mlp_norm,
                    &mut scratch.hn[l * d..(l + 1) * d],
                );
            }
            scratch.ff[..n * ffd].fill(0.0);
            let hn = &scratch.hn[..n * d];
            self.matmul_idx(lp.w1, n, d, ffd, hn, &mut scratch.ff[..n * ffd], &mut scratch.quant);
            for v in scratch.ff[..n * ffd].iter_mut() {
                *v = gelu(*v);
            }
            let ff = &scratch.ff[..n * ffd];
            self.matmul_idx(lp.w2, n, ffd, d, ff, &mut scratch.x[..n * d], &mut scratch.quant);
        }

        // Final norm + weight-tied head (logits[v] = dot(xn, embed[v])).
        let final_norm = self.plan.data(self.plan.final_norm);
        for l in 0..n {
            rmsnorm_into(
                &scratch.x[l * d..(l + 1) * d],
                final_norm,
                &mut scratch.hn[l * d..(l + 1) * d],
            );
        }
        for l in 0..n {
            let xn = &scratch.hn[l * d..(l + 1) * d];
            let out_l = &mut out[l * VOCAB..(l + 1) * VOCAB];
            match embed {
                TensorView::F32(e) => {
                    for (v, lo) in out_l.iter_mut().take(head_rows).enumerate() {
                        let row = &e[v * d..(v + 1) * d];
                        *lo = kernels::dot_f32(tier, xn, row);
                    }
                }
                TensorView::I8 { data, scales } => {
                    // Weight-tied int8 head: quantize this lane's normed
                    // state once, then one i32 dot + one scale multiply
                    // per coded logit row.
                    kernels::quantize_lanes(
                        tier,
                        1,
                        d,
                        xn,
                        &mut scratch.quant.qx,
                        &mut scratch.quant.sx,
                    );
                    let qxn = &scratch.quant.qx[..d];
                    let sx = scratch.quant.sx[0];
                    for (v, lo) in out_l.iter_mut().take(head_rows).enumerate() {
                        let row = &data[v * d..(v + 1) * d];
                        *lo = sx * scales[v] * kernels::dot_i8(tier, qxn, row) as f32;
                    }
                }
            }
            for lo in out_l.iter_mut().skip(head_rows) {
                *lo = 0.0;
            }
        }
        for lane in lanes.iter_mut() {
            lane.pos += 1;
        }
        Ok(())
    }

    /// Single-lane convenience wrapper over [`Self::advance_batch`]
    /// (allocates a one-lane scratch per call — samplers and tests only;
    /// the hot paths hold a persistent [`Scratch`]).
    pub fn advance(&self, st: &mut LaneState, token: u32) -> Result<Vec<f32>> {
        let mut scratch = Scratch::new(self.cfg, 1);
        let mut out = vec![0.0f32; VOCAB];
        self.advance_batch(std::slice::from_mut(st), &[token], &mut scratch, &mut out, VOCAB)?;
        Ok(out)
    }
}

/// A raw-pointer wrapper that may cross a channel into a pool worker.
///
/// SAFETY contract (upheld by [`NativeExecutor::step_into`]): the executor
/// sends each worker a disjoint span of the caller's `tokens`/`out`
/// buffers and then blocks until EVERY signalled worker has replied, so
/// the pointers never outlive the borrow they were derived from and no two
/// workers alias a span.
struct SpanPtr<T>(*const T);
// SAFETY: see the contract above — spans are disjoint and never outlive
// the borrow they were derived from.
unsafe impl<T: Send> Send for SpanPtr<T> {}
struct SpanPtrMut<T>(*mut T);
// SAFETY: same contract as `SpanPtr` above.
unsafe impl<T: Send> Send for SpanPtrMut<T> {}

/// One handoff to a persistent pool worker.
enum PoolJob {
    /// Advance this worker's lanes by one token each; `n` is the worker's
    /// lane count, `tokens`/`out` point at its span of the step buffers.
    Step { tokens: SpanPtr<u32>, out: SpanPtrMut<f32>, n: usize, head_rows: usize },
    /// Reset every owned lane to position 0.
    Reset,
}

/// A persistent engine worker: permanently owns a disjoint span of lanes
/// and its own scratch arena; woken per step by a channel send, replies on
/// its private done channel. Lives until the executor drops its `job_tx`.
struct PoolWorker {
    job_tx: Sender<PoolJob>,
    done_rx: Receiver<Result<()>>,
    n_lanes: usize,
    handle: Option<std::thread::JoinHandle<()>>,
}

fn pool_worker_main(
    model: Arc<NativeModel>,
    mut lanes: Vec<LaneState>,
    mut scratch: Scratch,
    rx: Receiver<PoolJob>,
    tx: Sender<Result<()>>,
) {
    while let Ok(job) = rx.recv() {
        let reply = match job {
            PoolJob::Reset => {
                for l in lanes.iter_mut() {
                    l.reset();
                }
                Ok(())
            }
            PoolJob::Step { tokens, out, n, head_rows } => {
                if n != lanes.len() {
                    Err(anyhow::anyhow!("pool worker got {n} tokens for {} lanes", lanes.len()))
                } else {
                    // SAFETY: see `SpanPtr` — the executor keeps these
                    // buffers alive and unaliased until our reply lands.
                    let toks = unsafe { std::slice::from_raw_parts(tokens.0, n) };
                    // SAFETY: same span contract as `toks` above.
                    let out = unsafe { std::slice::from_raw_parts_mut(out.0, n * VOCAB) };
                    model.advance_batch(&mut lanes, toks, &mut scratch, out, head_rows)
                }
            }
        };
        if tx.send(reply).is_err() {
            return; // executor is gone
        }
    }
}

/// One lane span of one executor's step, queued into a shared [`StepPool`].
///
/// Carries everything needed to advance the span: the model handle, raw
/// pointers into the owning executor's lane/token/logit buffers, and the
/// step's completion barrier. SAFETY: same contract as [`SpanPtr`] — the
/// owning executor blocks until the barrier drains, so the pointers never
/// outlive their borrows and no two tasks alias a span.
struct StealTask {
    model: Arc<NativeModel>,
    lanes: SpanPtrMut<LaneState>,
    tokens: SpanPtr<u32>,
    out: SpanPtrMut<f32>,
    n: usize,
    head_rows: usize,
    done: Arc<StepBarrier>,
}

/// Completion barrier for one fanned-out step: counts outstanding span
/// tasks and keeps the first error.
struct StepBarrier {
    /// (remaining tasks, first error).
    state: Mutex<(usize, Option<anyhow::Error>)>,
    done: Condvar,
}

impl StepBarrier {
    fn new(n_tasks: usize) -> Arc<StepBarrier> {
        Arc::new(StepBarrier { state: Mutex::new((n_tasks, None)), done: Condvar::new() })
    }

    fn complete(&self, result: Result<()>) {
        let mut s = self.state.lock().unwrap();
        s.0 -= 1;
        if let Err(e) = result {
            if s.1.is_none() {
                s.1 = Some(e);
            }
        }
        if s.0 == 0 {
            self.done.notify_all();
        }
    }
}

/// Run one span task with a scratch arena that is already known to match
/// its model config and capacity. A panicking span must not kill a shared
/// pool thread (it would wedge EVERY replica's barrier), so it is contained
/// and reported as a failed step.
fn run_steal_task(task: StealTask, scratch: &mut Scratch) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // SAFETY: see `StealTask` — the owning executor keeps these
        // buffers alive and unaliased until our `complete` lands.
        let lanes = unsafe { std::slice::from_raw_parts_mut(task.lanes.0, task.n) };
        // SAFETY: same span contract as `lanes` above.
        let toks = unsafe { std::slice::from_raw_parts(task.tokens.0, task.n) };
        // SAFETY: same span contract as `lanes` above.
        let out = unsafe { std::slice::from_raw_parts_mut(task.out.0, task.n * VOCAB) };
        task.model.advance_batch(lanes, toks, scratch, out, task.head_rows)
    }))
    .unwrap_or_else(|_| Err(anyhow::anyhow!("shared-pool step span panicked")));
    task.done.complete(result);
}

/// The shared injector: span tasks from every attached executor, drained
/// by the pool threads and by stepping callers.
struct StealShared {
    queue: Mutex<VecDeque<StealTask>>,
    available: Condvar,
    shutdown: AtomicBool,
    /// Desired live worker-thread count. [`StepPool::resize`] moves it at
    /// runtime; surplus workers retire at their next wakeup, BETWEEN
    /// tasks — a mid-span retirement could wedge a step barrier.
    target: AtomicUsize,
    /// Worker threads currently alive (retired threads decrement on exit).
    alive: AtomicUsize,
}

/// A work-stealing step pool shared by any number of [`NativeExecutor`]
/// replicas (attach with [`NativeExecutor::with_shared_pool`]).
///
/// Long-lived OS threads service one global injector queue of lane-span
/// tasks. Replicas are expected to be homogeneous (same [`LmConfig`]); a
/// heterogeneous pool still computes correctly but re-allocates per-thread
/// scratch when configs alternate. A zero-thread pool is valid: every
/// step is then executed entirely by its caller (useful for tests and as
/// the degenerate sizing).
///
/// The thread count is **elastic**: [`StepPool::resize`] grows or shrinks
/// the worker set at runtime, so an autoscaling coordinator can keep the
/// step-thread budget proportional to its live replica gauge instead of
/// provisioning for `max_replicas` up front. Sizing is a pure execution
/// knob — spans are lane-disjoint and per-lane arithmetic is fixed, so the
/// logits (and therefore the container bytes) are bit-identical for every
/// pool size and every resize schedule.
pub struct StepPool {
    shared: Arc<StealShared>,
    /// Handles of every thread ever spawned; joined at drop (retired
    /// threads have already exited — their join is immediate).
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Monotonic name counter for spawned workers.
    next_worker: AtomicUsize,
}

impl StepPool {
    /// Spawn a pool with `threads` stealing worker threads.
    pub fn new(threads: usize) -> Arc<StepPool> {
        let shared = Arc::new(StealShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            target: AtomicUsize::new(threads),
            alive: AtomicUsize::new(0),
        });
        let pool =
            StepPool { shared, handles: Mutex::new(Vec::new()), next_worker: AtomicUsize::new(0) };
        pool.spawn_to_target();
        Arc::new(pool)
    }

    /// Live worker-thread target (the sizing callers see; also the span
    /// fan-out hint for [`NativeExecutor`] steps).
    pub fn threads(&self) -> usize {
        self.shared.target.load(Ordering::SeqCst)
    }

    /// Retarget the pool to `threads` workers. Growth spawns immediately;
    /// shrink retires surplus workers at their next wakeup (never mid
    /// span). Safe to call concurrently with active steps from any number
    /// of replicas: sizing cannot change the bytes, only the parallelism.
    pub fn resize(&self, threads: usize) {
        self.shared.target.store(threads, Ordering::SeqCst);
        // Reap threads retired by earlier shrinks, so a long-lived server
        // flapping between sizes doesn't accumulate unjoined handles (an
        // exited-but-unjoined pthread keeps its stack mapping alive).
        self.reap_finished();
        self.spawn_to_target();
        // Wake sleepers so surplus workers notice the lower target.
        self.shared.available.notify_all();
    }

    /// Join (and drop) the handles of workers that have already exited.
    fn reap_finished(&self) {
        let mut handles = self.handles.lock().unwrap();
        let mut i = 0;
        while i < handles.len() {
            if handles[i].is_finished() {
                let _ = handles.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
    }

    /// Spawn workers until `alive` meets the target (CAS-claimed so
    /// concurrent resizes never over-spawn).
    fn spawn_to_target(&self) {
        loop {
            let target = self.shared.target.load(Ordering::SeqCst);
            let alive = self.shared.alive.load(Ordering::SeqCst);
            if alive >= target || self.shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if self
                .shared
                .alive
                .compare_exchange(alive, alive + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_err()
            {
                continue;
            }
            let sh = self.shared.clone();
            let id = self.next_worker.fetch_add(1, Ordering::Relaxed);
            let handle = std::thread::Builder::new()
                .name(format!("llmzip-steal-{id}"))
                .spawn(move || steal_worker_main(sh))
                .expect("spawning steal worker");
            self.handles.lock().unwrap().push(handle);
        }
    }

    fn push_tasks(&self, tasks: Vec<StealTask>) {
        let mut q = self.shared.queue.lock().unwrap();
        q.extend(tasks);
        drop(q);
        self.shared.available.notify_all();
    }

    fn try_pop(&self) -> Option<StealTask> {
        self.shared.queue.lock().unwrap().pop_front()
    }

    /// Re-queue a task the popper cannot run (wrong config / too wide for
    /// its scratch); it goes to the BACK so the queue keeps rotating.
    fn push_back(&self, task: StealTask) {
        self.shared.queue.lock().unwrap().push_back(task);
        self.shared.available.notify_all();
    }
}

impl Drop for StepPool {
    fn drop(&mut self) {
        // Executors hold this pool behind an Arc, so by the time Drop runs
        // no step can be in flight: the queue is empty of live tasks.
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// A pool thread: block on the injector, run spans from ANY attached
/// executor. One cached scratch arena, rebuilt only when a span needs a
/// different model config or a wider capacity (steady state with
/// homogeneous replicas allocates nothing). Exits when the pool shuts
/// down or a [`StepPool::resize`] lowered the target below the live
/// count — always between tasks, never inside one.
fn steal_worker_main(shared: Arc<StealShared>) {
    let mut scratch: Option<(usize, Scratch)> = None;
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    shared.alive.fetch_sub(1, Ordering::SeqCst);
                    return;
                }
                // Elastic shrink: retire if we are surplus. The CAS makes
                // exactly (alive - target) workers retire, even when many
                // wake at once.
                let alive = shared.alive.load(Ordering::SeqCst);
                if alive > shared.target.load(Ordering::SeqCst)
                    && shared
                        .alive
                        .compare_exchange(alive, alive - 1, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        let cfg = task.model.cfg;
        let key = cfg as *const LmConfig as usize;
        let rebuild = match &scratch {
            Some((k, s)) => *k != key || s.capacity() < task.n,
            None => true,
        };
        if rebuild {
            scratch = Some((key, Scratch::new(cfg, task.n)));
        }
        let (_, s) = scratch.as_mut().expect("scratch just ensured");
        run_steal_task(task, s);
    }
}

/// Native executor: a shared [`NativeModel`] plus either an inline lane
/// pool (`threads == 1`), a persistent worker pool (`threads > 1`), or a
/// cross-replica shared [`StepPool`] (`with_shared_pool`).
pub struct NativeExecutor {
    model: Arc<NativeModel>,
    n_lanes: usize,
    threads: usize,
    head_rows: usize,
    /// `threads == 1` or shared-pool mode: lanes + scratch live inline.
    local: Option<(Vec<LaneState>, Scratch)>,
    /// `threads > 1` (private pool): persistent workers own the lanes.
    workers: Vec<PoolWorker>,
    /// Shared-pool mode: steps fan lane spans into this injector instead
    /// of a private pool (lanes stay inline; siblings steal spans).
    steal_pool: Option<Arc<StepPool>>,
}

impl NativeExecutor {
    /// Accepts either an owned `Weights` or an `Arc<Weights>` shared with
    /// other replicas (the coordinator's replica pool passes the latter,
    /// so N executors cost one copy of the tensors).
    pub fn new(cfg: &'static LmConfig, weights: impl Into<Arc<Weights>>, n_lanes: usize) -> Self {
        Self::with_opts(cfg, weights, n_lanes, KernelOptions::default())
            .expect("weights were validated against param_spec at load")
    }

    /// [`NativeExecutor::new`] with explicit [`KernelOptions`] (forced
    /// dispatch tier and/or panel layout off). Errors if the requested
    /// tier is unavailable on this CPU.
    pub fn with_opts(
        cfg: &'static LmConfig,
        weights: impl Into<Arc<Weights>>,
        n_lanes: usize,
        opts: KernelOptions,
    ) -> Result<Self> {
        let model = Arc::new(NativeModel::with_opts(cfg, weights, opts)?);
        let local = Some((
            (0..n_lanes).map(|_| LaneState::new(cfg, MAX_CONTEXT)).collect(),
            Scratch::new(cfg, n_lanes),
        ));
        Ok(NativeExecutor {
            model,
            n_lanes,
            threads: 1,
            head_rows: VOCAB,
            local,
            workers: Vec::new(),
            steal_pool: None,
        })
    }

    /// The kernel dispatch tier the underlying model resolved at load.
    pub fn tier(&self) -> KernelTier {
        self.model.kernel_tier()
    }

    /// Partition lanes across `threads` persistent worker threads (clamped
    /// to `1..=lanes`). Each worker permanently owns a disjoint lane span
    /// and its own scratch arena; per step it is woken by a channel send
    /// instead of a `thread::scope` spawn, so the handoff costs
    /// microseconds even for nano-sized models. Bit-exact for any thread
    /// count: lanes are computed independently. Resets all lane state.
    pub fn with_threads(mut self, threads: usize) -> Self {
        // Exclusive with `with_shared_pool`: the later call wins.
        self.steal_pool = None;
        let t = threads.clamp(1, self.n_lanes.max(1));
        self.spawn_pool(t);
        self
    }

    /// Route this executor's steps through a cross-replica [`StepPool`]
    /// instead of a private worker pool: each step fans disjoint lane
    /// spans into the pool's injector, and the pool's threads plus this
    /// caller drain them (stealing sibling replicas' spans while waiting).
    /// Lanes stay owned by this executor, so replicas attach and detach
    /// without thread churn — which is what makes autoscale-grown replicas
    /// cheap. Bit-exact for any pool size (including zero threads, where
    /// the caller computes everything). Resets all lane state.
    pub fn with_shared_pool(mut self, pool: Arc<StepPool>) -> Self {
        // Tear down any private pool and bring lanes back inline.
        self.spawn_pool(1);
        self.steal_pool = Some(pool);
        self
    }

    /// The shared step pool this executor is attached to, if any.
    pub fn shared_pool(&self) -> Option<&Arc<StepPool>> {
        self.steal_pool.as_ref()
    }

    fn spawn_pool(&mut self, t: usize) {
        self.shutdown_pool();
        self.threads = t;
        if t == 1 {
            self.local = Some((
                (0..self.n_lanes).map(|_| LaneState::new(self.model.cfg, MAX_CONTEXT)).collect(),
                Scratch::new(self.model.cfg, self.n_lanes),
            ));
            return;
        }
        self.local = None;
        let per = self.n_lanes.div_ceil(t);
        let mut start = 0usize;
        while start < self.n_lanes {
            let n = per.min(self.n_lanes - start);
            let cfg = self.model.cfg;
            let lanes: Vec<LaneState> = (0..n).map(|_| LaneState::new(cfg, MAX_CONTEXT)).collect();
            let scratch = Scratch::new(cfg, n);
            let model = self.model.clone();
            let (job_tx, job_rx) = channel();
            let (done_tx, done_rx) = channel();
            let handle = std::thread::Builder::new()
                .name(format!("llmzip-step-{start}"))
                .spawn(move || pool_worker_main(model, lanes, scratch, job_rx, done_tx))
                .expect("spawning engine pool worker");
            self.workers.push(PoolWorker { job_tx, done_rx, n_lanes: n, handle: Some(handle) });
            start += n;
        }
    }

    fn shutdown_pool(&mut self) {
        for w in self.workers.drain(..) {
            // Dropping the job sender disconnects the worker's recv loop.
            drop(w.job_tx);
            drop(w.done_rx);
            if let Some(h) = w.handle {
                let _ = h.join();
            }
        }
    }

    /// Restrict the output head to the first `rows` logit rows (the rest
    /// are zeroed). The compressor passes
    /// [`crate::lm::config::CODED_BYTES`]; default is the full [`VOCAB`].
    pub fn with_head_rows(mut self, rows: usize) -> Self {
        self.head_rows = rows.clamp(1, VOCAB);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Shared-pool step: fan this step's disjoint lane spans into the
    /// injector, then help drain the queue — running our spans or a
    /// sibling replica's — until our barrier completes. Correctness never
    /// depends on the pool threads: with all of them busy elsewhere (or a
    /// zero-thread pool), this loop executes every span itself.
    fn step_into_shared(&mut self, pool: &StepPool, tokens: &[u32], out: &mut [f32]) -> Result<()> {
        let n = self.n_lanes;
        if n == 0 {
            return Ok(());
        }
        let model = self.model.clone();
        let head_rows = self.head_rows;
        let (lanes, scratch) = self.local.as_mut().expect("shared-pool mode keeps lanes inline");
        // Span granularity: enough spans for every pool thread plus this
        // caller, so ONE busy replica can spread across the whole pool.
        let spans = (pool.threads() + 1).min(n);
        let per = n.div_ceil(spans);
        let n_tasks = n.div_ceil(per);
        let barrier = StepBarrier::new(n_tasks);
        let lanes_ptr = lanes.as_mut_ptr();
        let mut tasks = Vec::with_capacity(n_tasks);
        let mut start = 0usize;
        while start < n {
            let len = per.min(n - start);
            tasks.push(StealTask {
                model: model.clone(),
                // SAFETY: `start < n <= lanes.len()`, spans are disjoint, and
                // this method does not return until the barrier drains (see
                // `StealTask`).
                lanes: SpanPtrMut(unsafe { lanes_ptr.add(start) }),
                tokens: SpanPtr(tokens[start..].as_ptr()),
                out: SpanPtrMut(out[start * VOCAB..].as_mut_ptr()),
                n: len,
                head_rows,
                done: barrier.clone(),
            });
            start += len;
        }
        pool.push_tasks(tasks);
        let own_cfg = model.cfg as *const LmConfig;
        loop {
            let mut ran = false;
            // Help drain the queue — but ONLY while our own step is still
            // outstanding. Once the barrier is down we return immediately
            // instead of adopting an unbounded stream of sibling spans
            // (that would delay this replica's completion report under
            // sustained load).
            while barrier.state.lock().unwrap().0 > 0 {
                let Some(task) = pool.try_pop() else { break };
                if std::ptr::eq(task.model.cfg as *const LmConfig, own_cfg)
                    && task.n <= scratch.capacity()
                {
                    run_steal_task(task, scratch);
                    ran = true;
                } else {
                    // A span our scratch can't serve (heterogeneous pool):
                    // rotate it to the back for a matching runner.
                    pool.push_back(task);
                    break;
                }
            }
            let state = barrier.state.lock().unwrap();
            if state.0 == 0 {
                break;
            }
            if !ran {
                // Our remaining spans are in flight on pool threads (or
                // queued behind a span we can't run): sleep on the
                // barrier, with a timeout so re-queued spans get
                // re-checked.
                let _ = barrier.done.wait_timeout(state, Duration::from_micros(200)).unwrap();
            }
        }
        let mut state = barrier.state.lock().unwrap();
        match state.1.take() {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

impl Drop for NativeExecutor {
    fn drop(&mut self) {
        self.shutdown_pool();
    }
}

impl crate::lm::executor::LmExecutor for NativeExecutor {
    fn config(&self) -> &'static LmConfig {
        self.model.cfg
    }

    fn kind(&self) -> crate::lm::executor::ExecutorKind {
        crate::lm::executor::ExecutorKind::Native
    }

    fn lanes(&self) -> usize {
        self.n_lanes
    }

    fn kernel_tier(&self) -> &'static str {
        self.model.kernel_tier().as_str()
    }

    fn reset(&mut self) {
        if let Some((lanes, _)) = self.local.as_mut() {
            for l in lanes.iter_mut() {
                l.reset();
            }
            return;
        }
        let mut sent = 0usize;
        for w in &self.workers {
            if w.job_tx.send(PoolJob::Reset).is_err() {
                break;
            }
            sent += 1;
        }
        for w in self.workers.iter().take(sent) {
            let _ = w.done_rx.recv();
        }
    }

    fn step(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.n_lanes * VOCAB];
        self.step_into(tokens, &mut out)?;
        Ok(out)
    }

    /// Zero-allocation step: all intermediates live in the preallocated
    /// scratch arenas, the logits land in the caller's buffer. With
    /// `threads > 1` the step is a channel handoff to the persistent
    /// worker pool — no thread spawn/join anywhere in steady state.
    fn step_into(&mut self, tokens: &[u32], out: &mut [f32]) -> Result<()> {
        let n = self.n_lanes;
        if tokens.len() != n {
            anyhow::bail!("step expects {} lane tokens, got {}", n, tokens.len());
        }
        if out.len() != n * VOCAB {
            anyhow::bail!("step expects out buffer of {}, got {}", n * VOCAB, out.len());
        }
        if let Some(pool) = self.steal_pool.clone() {
            return self.step_into_shared(&pool, tokens, out);
        }
        if let Some((lanes, scratch)) = self.local.as_mut() {
            return self.model.advance_batch(lanes, tokens, scratch, out, self.head_rows);
        }
        // Fan the step out to the pool: each worker gets its disjoint span.
        let head_rows = self.head_rows;
        let mut off = 0usize;
        let mut sent = 0usize;
        let mut worker_died = false;
        for w in &self.workers {
            let job = PoolJob::Step {
                tokens: SpanPtr(tokens[off..].as_ptr()),
                out: SpanPtrMut(out[off * VOCAB..].as_mut_ptr()),
                n: w.n_lanes,
                head_rows,
            };
            if w.job_tx.send(job).is_err() {
                worker_died = true;
                break;
            }
            off += w.n_lanes;
            sent += 1;
        }
        // Barrier: collect a reply from every signalled worker before
        // returning, so no worker retains a pointer into the caller's
        // buffers (this is what makes the SpanPtr handoff sound).
        let mut first_err: Option<anyhow::Error> = None;
        for w in self.workers.iter().take(sent) {
            match w.done_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    worker_died = true;
                }
            }
        }
        if first_err.is_none() && worker_died {
            first_err = Some(anyhow::anyhow!("engine pool worker died"));
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::{by_name, CODED_BYTES};
    use crate::lm::executor::LmExecutor;
    use crate::tokenizer::vocab::BOS;

    fn softmax(logits: &[f32]) -> Vec<f32> {
        let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
        let s: f32 = e.iter().sum();
        e.into_iter().map(|x| x / s).collect()
    }

    #[test]
    fn advance_is_deterministic_and_replayable() {
        let cfg = by_name("nano").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 1));
        let tokens = [BOS, 72, 101, 108, 108, 111];
        let mut st1 = LaneState::new(cfg, 16);
        let run1: Vec<Vec<f32>> =
            tokens.iter().map(|&t| model.advance(&mut st1, t).unwrap()).collect();
        let mut st2 = LaneState::new(cfg, 16);
        let run2: Vec<Vec<f32>> =
            tokens.iter().map(|&t| model.advance(&mut st2, t).unwrap()).collect();
        assert_eq!(run1, run2, "bit-exact replay");
    }

    #[test]
    fn logits_are_finite_and_distribution_valid() {
        let cfg = by_name("tiny").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 2));
        let mut st = LaneState::new(cfg, 32);
        for &t in &[BOS, 10, 200, 65, 0, 255] {
            let logits = model.advance(&mut st, t).unwrap();
            assert_eq!(logits.len(), VOCAB);
            assert!(logits.iter().all(|x| x.is_finite()));
            let p = softmax(&logits);
            let sum: f32 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn prefix_property_holds() {
        // Logits after feeding prefix P are identical regardless of what
        // would come later (trivially true for the incremental formulation,
        // but this guards against accidental lookahead bugs).
        let cfg = by_name("nano").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 3));
        let mut a = LaneState::new(cfg, 16);
        let la = model.advance(&mut a, BOS).unwrap();
        let mut b = LaneState::new(cfg, 16);
        let lb = model.advance(&mut b, BOS).unwrap();
        model.advance(&mut b, 42).unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn lane_overflow_rejected() {
        let cfg = by_name("nano").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 4));
        let mut st = LaneState::new(cfg, 4);
        for _ in 0..4 {
            model.advance(&mut st, 65).unwrap();
        }
        assert!(model.advance(&mut st, 65).is_err());
    }

    #[test]
    fn executor_steps_all_lanes() {
        let cfg = by_name("nano").unwrap();
        let mut ex = NativeExecutor::new(cfg, Weights::random(cfg, 5), 3);
        let out = ex.step(&[BOS, BOS, BOS]).unwrap();
        assert_eq!(out.len(), 3 * VOCAB);
        // Same token in every lane from fresh state -> identical logits.
        assert_eq!(out[..VOCAB], out[VOCAB..2 * VOCAB]);
        assert!(ex.step(&[1, 2]).is_err());
    }

    #[test]
    fn context_changes_prediction() {
        let cfg = by_name("tiny").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 6));
        let mut a = LaneState::new(cfg, 8);
        model.advance(&mut a, BOS).unwrap();
        let la = model.advance(&mut a, 65).unwrap();
        let mut b = LaneState::new(cfg, 8);
        model.advance(&mut b, BOS).unwrap();
        let lb = model.advance(&mut b, 90).unwrap();
        assert_ne!(la, lb, "different contexts must give different logits");
    }

    #[test]
    fn batch_matches_single_lane_bit_for_bit() {
        // The batched path restructures the loops (lanes through each layer
        // together) but must reproduce the single-lane path exactly.
        let cfg = by_name("small").unwrap();
        let w = Weights::random(cfg, 7);
        let model = NativeModel::new(cfg, w);
        let seqs: [&[u32]; 3] = [&[BOS, 72, 101, 108], &[BOS, 10, 200, 65], &[BOS, 0, 255, 90]];
        // Serial: one lane at a time via advance().
        let mut serial = Vec::new();
        for seq in &seqs {
            let mut st = LaneState::new(cfg, 16);
            let mut per_step = Vec::new();
            for &t in *seq {
                per_step.push(model.advance(&mut st, t).unwrap());
            }
            serial.push(per_step);
        }
        // Batched: all three lanes per step.
        let mut lanes: Vec<LaneState> = (0..3).map(|_| LaneState::new(cfg, 16)).collect();
        let mut scratch = Scratch::new(cfg, 3);
        let mut out = vec![0.0f32; 3 * VOCAB];
        for t in 0..seqs[0].len() {
            let toks: Vec<u32> = seqs.iter().map(|s| s[t]).collect();
            model.advance_batch(&mut lanes, &toks, &mut scratch, &mut out, VOCAB).unwrap();
            for l in 0..3 {
                assert_eq!(
                    out[l * VOCAB..(l + 1) * VOCAB],
                    serial[l][t][..],
                    "lane {l} step {t}"
                );
            }
        }
    }

    #[test]
    fn threaded_step_matches_single_thread() {
        let cfg = by_name("medium").unwrap();
        let w = Weights::random(cfg, 8);
        let mut one = NativeExecutor::new(cfg, w.clone(), 8);
        let mut two = NativeExecutor::new(cfg, w, 8).with_threads(2);
        assert_eq!(two.threads(), 2);
        for step in 0..3u32 {
            let toks: Vec<u32> = (0..8).map(|l| (40 + l * 13 + step) % 256).collect();
            let a = one.step(&toks).unwrap();
            let b = two.step(&toks).unwrap();
            assert_eq!(a, b, "step {step}");
        }
    }

    #[test]
    fn persistent_pool_bit_exact_for_any_thread_count() {
        // The pool has no work gate: even a nano model genuinely fans out
        // to the persistent workers. Every thread count must reproduce the
        // single-threaded logits exactly, across resets.
        let cfg = by_name("nano").unwrap();
        let w = std::sync::Arc::new(Weights::random(cfg, 21));
        let mut baseline = NativeExecutor::new(cfg, w.clone(), 5);
        let mut pooled: Vec<NativeExecutor> = [2usize, 3, 5, 8]
            .iter()
            .map(|&t| NativeExecutor::new(cfg, w.clone(), 5).with_threads(t))
            .collect();
        assert_eq!(pooled[3].threads(), 5, "threads clamp to lane count");
        for round in 0..2 {
            baseline.reset();
            for ex in pooled.iter_mut() {
                ex.reset();
            }
            for step in 0..4u32 {
                let toks: Vec<u32> = (0..5).map(|l| (l * 41 + step * 7 + round) % 256).collect();
                let a = baseline.step(&toks).unwrap();
                for ex in pooled.iter_mut() {
                    assert_eq!(a, ex.step(&toks).unwrap(), "round {round} step {step}");
                }
            }
        }
    }

    #[test]
    fn pool_replicas_share_one_weight_bundle() {
        let cfg = by_name("nano").unwrap();
        let w = std::sync::Arc::new(Weights::random(cfg, 22));
        let a = NativeExecutor::new(cfg, w.clone(), 2).with_threads(2);
        let b = NativeExecutor::new(cfg, w.clone(), 2);
        assert!(std::ptr::eq(
            a.model().weights().data(0).as_ptr(),
            b.model().weights().data(0).as_ptr()
        ));
        // 1 local + the two executors' models (pool workers share each
        // executor's Arc<NativeModel>, not a second weights Arc).
        assert_eq!(std::sync::Arc::strong_count(&w), 3);
    }

    #[test]
    fn pool_head_rows_and_validation_still_apply() {
        let cfg = by_name("nano").unwrap();
        let w = Weights::random(cfg, 23);
        let mut full = NativeExecutor::new(cfg, w.clone(), 2);
        let mut coded = NativeExecutor::new(cfg, w, 2).with_threads(2).with_head_rows(CODED_BYTES);
        let toks = [BOS, 70];
        let a = full.step(&toks).unwrap();
        let b = coded.step(&toks).unwrap();
        for l in 0..2 {
            let coded = l * VOCAB..l * VOCAB + CODED_BYTES;
            assert_eq!(a[coded.clone()], b[coded]);
            assert!(b[l * VOCAB + CODED_BYTES..(l + 1) * VOCAB].iter().all(|&x| x == 0.0));
        }
        assert!(coded.step(&[BOS]).is_err(), "wrong token count rejected by pool path");
    }

    #[test]
    fn head_rows_matches_full_head_on_coded_bytes() {
        let cfg = by_name("nano").unwrap();
        let w = Weights::random(cfg, 9);
        let mut full = NativeExecutor::new(cfg, w.clone(), 2);
        let mut coded = NativeExecutor::new(cfg, w, 2).with_head_rows(CODED_BYTES);
        let toks = [BOS, 65];
        let a = full.step(&toks).unwrap();
        let b = coded.step(&toks).unwrap();
        for l in 0..2 {
            assert_eq!(
                a[l * VOCAB..l * VOCAB + CODED_BYTES],
                b[l * VOCAB..l * VOCAB + CODED_BYTES],
                "coded region must be bit-identical"
            );
            assert!(b[l * VOCAB + CODED_BYTES..(l + 1) * VOCAB].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn int8_advance_is_deterministic_and_replayable() {
        let cfg = by_name("small").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 31).quantize());
        let tokens = [BOS, 72, 101, 108, 108, 111];
        let mut st1 = LaneState::new(cfg, 16);
        let run1: Vec<Vec<f32>> =
            tokens.iter().map(|&t| model.advance(&mut st1, t).unwrap()).collect();
        let mut st2 = LaneState::new(cfg, 16);
        let run2: Vec<Vec<f32>> =
            tokens.iter().map(|&t| model.advance(&mut st2, t).unwrap()).collect();
        assert_eq!(run1, run2, "bit-exact int8 replay");
        assert!(run1.iter().flatten().all(|x| x.is_finite()));
        // Int8 logits approximate but don't equal the f32 logits.
        let f32_model = NativeModel::new(cfg, Weights::random(cfg, 31));
        let mut st3 = LaneState::new(cfg, 16);
        let f32_run: Vec<Vec<f32>> =
            tokens.iter().map(|&t| f32_model.advance(&mut st3, t).unwrap()).collect();
        assert_ne!(run1, f32_run, "quantization must actually change the numerics");
    }

    #[test]
    fn int8_batch_matches_single_lane_bit_for_bit() {
        // The lossless-decode requirement for the quantized path: lane
        // batching must be a pure execution knob, exactly like f32.
        let cfg = by_name("tiny").unwrap();
        let w = Weights::random(cfg, 32).quantize();
        let model = NativeModel::new(cfg, w);
        let seqs: [&[u32]; 3] = [&[BOS, 72, 101, 108], &[BOS, 10, 200, 65], &[BOS, 0, 255, 90]];
        let mut serial = Vec::new();
        for seq in &seqs {
            let mut st = LaneState::new(cfg, 16);
            let mut per_step = Vec::new();
            for &t in *seq {
                per_step.push(model.advance(&mut st, t).unwrap());
            }
            serial.push(per_step);
        }
        let mut lanes: Vec<LaneState> = (0..3).map(|_| LaneState::new(cfg, 16)).collect();
        let mut scratch = Scratch::new(cfg, 3);
        let mut out = vec![0.0f32; 3 * VOCAB];
        for t in 0..seqs[0].len() {
            let toks: Vec<u32> = seqs.iter().map(|s| s[t]).collect();
            model.advance_batch(&mut lanes, &toks, &mut scratch, &mut out, VOCAB).unwrap();
            for l in 0..3 {
                assert_eq!(out[l * VOCAB..(l + 1) * VOCAB], serial[l][t][..], "lane {l} step {t}");
            }
        }
    }

    #[test]
    fn int8_pool_bit_exact_for_any_thread_count_and_head_rows() {
        let cfg = by_name("nano").unwrap();
        let w = std::sync::Arc::new(Weights::random(cfg, 33).quantize());
        let mut baseline = NativeExecutor::new(cfg, w.clone(), 4);
        let mut pooled = NativeExecutor::new(cfg, w.clone(), 4).with_threads(4);
        for step in 0..3u32 {
            let toks: Vec<u32> = (0..4).map(|l| (l * 29 + step * 17 + 3) % 256).collect();
            assert_eq!(baseline.step(&toks).unwrap(), pooled.step(&toks).unwrap(), "step {step}");
        }
        // Coded-only head matches the full head on the coded rows.
        let mut full = NativeExecutor::new(cfg, w.clone(), 2);
        let mut coded = NativeExecutor::new(cfg, w, 2).with_head_rows(CODED_BYTES);
        let toks = [BOS, 70];
        let a = full.step(&toks).unwrap();
        let b = coded.step(&toks).unwrap();
        for l in 0..2 {
            let coded_range = l * VOCAB..l * VOCAB + CODED_BYTES;
            assert_eq!(a[coded_range.clone()], b[coded_range]);
            assert!(b[l * VOCAB + CODED_BYTES..(l + 1) * VOCAB].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn shared_pool_bit_exact_for_any_pool_size() {
        // Work stealing is a pure execution knob: an executor attached to
        // a StepPool of ANY size (including zero threads, where the caller
        // computes every span itself) must reproduce the single-threaded
        // logits exactly, across resets.
        let cfg = by_name("nano").unwrap();
        let w = std::sync::Arc::new(Weights::random(cfg, 41));
        let mut baseline = NativeExecutor::new(cfg, w.clone(), 5);
        let pools: Vec<std::sync::Arc<StepPool>> =
            [0usize, 1, 3].iter().map(|&t| StepPool::new(t)).collect();
        let mut pooled: Vec<NativeExecutor> = pools
            .iter()
            .map(|p| NativeExecutor::new(cfg, w.clone(), 5).with_shared_pool(p.clone()))
            .collect();
        assert!(pooled[0].shared_pool().is_some());
        for round in 0..2 {
            baseline.reset();
            for ex in pooled.iter_mut() {
                ex.reset();
            }
            for step in 0..4u32 {
                let toks: Vec<u32> = (0..5).map(|l| (l * 37 + step * 11 + round) % 256).collect();
                let a = baseline.step(&toks).unwrap();
                for (i, ex) in pooled.iter_mut().enumerate() {
                    assert_eq!(a, ex.step(&toks).unwrap(), "pool {i} round {round} step {step}");
                }
            }
        }
    }

    #[test]
    fn shared_pool_resize_is_invisible_in_the_logits() {
        // Elastic sizing mid-stream: grow and shrink the pool between
        // (and across) steps; every logit must stay identical to the
        // single-threaded reference. Shrinking to zero is valid — the
        // stepping caller then runs every span itself.
        let cfg = by_name("nano").unwrap();
        let w = std::sync::Arc::new(Weights::random(cfg, 45));
        let mut baseline = NativeExecutor::new(cfg, w.clone(), 5);
        let pool = StepPool::new(1);
        let mut ex = NativeExecutor::new(cfg, w, 5).with_shared_pool(pool.clone());
        let sizes = [1usize, 4, 0, 2, 0, 3];
        for (step, &size) in sizes.iter().enumerate() {
            pool.resize(size);
            assert_eq!(pool.threads(), size);
            let toks: Vec<u32> = (0..5).map(|l| ((l * 43 + step * 13) % 256) as u32).collect();
            assert_eq!(
                baseline.step(&toks).unwrap(),
                ex.step(&toks).unwrap(),
                "step {step} at pool size {size}"
            );
        }
        // Idempotent + monotone retargeting settles cleanly.
        pool.resize(2);
        pool.resize(2);
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn shared_pool_two_replicas_stepping_concurrently_stay_bit_exact() {
        // Two replicas share ONE pool and step at the same time from two
        // threads — spans interleave through the injector (and each caller
        // may steal the other's spans), yet both must match the
        // single-threaded reference exactly.
        let cfg = by_name("nano").unwrap();
        let w = std::sync::Arc::new(Weights::random(cfg, 42));
        // Reference logits per step, computed single-threaded.
        let mut reference = NativeExecutor::new(cfg, w.clone(), 4);
        let toks_at = |step: u32| -> Vec<u32> { (0..4).map(|l| (l * 53 + step * 19) % 256).collect() };
        let expected: Vec<Vec<f32>> = (0..6u32)
            .map(|s| {
                if s == 3 {
                    reference.reset();
                }
                reference.step(&toks_at(s)).unwrap()
            })
            .collect();
        let pool = StepPool::new(2);
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let mut ex =
                    NativeExecutor::new(cfg, w.clone(), 4).with_shared_pool(pool.clone());
                let expected = expected.clone();
                std::thread::spawn(move || {
                    for _ in 0..3 {
                        ex.reset();
                        for (s, want) in expected.iter().enumerate() {
                            let s = s as u32;
                            if s == 3 {
                                ex.reset();
                            }
                            assert_eq!(&ex.step(&toks_at(s)).unwrap(), want, "step {s}");
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shared_pool_propagates_errors_and_recovers() {
        let cfg = by_name("nano").unwrap();
        let pool = StepPool::new(1);
        let mut ex =
            NativeExecutor::new(cfg, Weights::random(cfg, 43), 2).with_shared_pool(pool);
        // Wrong token count is rejected before any fan-out.
        assert!(ex.step(&[BOS]).is_err());
        // An invalid token fails the step through the barrier...
        assert!(ex.step(&[BOS, 9999]).is_err());
        // ...and the executor keeps serving after a reset.
        ex.reset();
        let a = ex.step(&[BOS, 70]).unwrap();
        let mut single = NativeExecutor::new(cfg, Weights::random(cfg, 43), 2);
        assert_eq!(a, single.step(&[BOS, 70]).unwrap());
    }

    #[test]
    fn shared_pool_int8_and_head_rows_stay_bit_exact() {
        let cfg = by_name("nano").unwrap();
        let w = std::sync::Arc::new(Weights::random(cfg, 44).quantize());
        let pool = StepPool::new(2);
        let mut full = NativeExecutor::new(cfg, w.clone(), 3);
        let mut coded = NativeExecutor::new(cfg, w, 3)
            .with_shared_pool(pool)
            .with_head_rows(CODED_BYTES);
        for step in 0..3u32 {
            let toks: Vec<u32> = (0..3).map(|l| (l * 61 + step * 23 + 1) % 256).collect();
            let a = full.step(&toks).unwrap();
            let b = coded.step(&toks).unwrap();
            for l in 0..3 {
                let r = l * VOCAB..l * VOCAB + CODED_BYTES;
                assert_eq!(a[r.clone()], b[r], "step {step} lane {l}");
                assert!(b[l * VOCAB + CODED_BYTES..(l + 1) * VOCAB].iter().all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn step_into_is_exact_and_validates_buffer() {
        let cfg = by_name("nano").unwrap();
        let mut ex = NativeExecutor::new(cfg, Weights::random(cfg, 10), 2);
        let mut buf = vec![0.0f32; 2 * VOCAB];
        ex.step_into(&[BOS, BOS], &mut buf).unwrap();
        ex.reset();
        let via_step = ex.step(&[BOS, BOS]).unwrap();
        assert_eq!(buf, via_step);
        let mut short = vec![0.0f32; VOCAB];
        assert!(ex.step_into(&[BOS, BOS], &mut short).is_err());
    }
}
