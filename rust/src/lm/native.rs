//! Native rust implementation of the transformer — the same architecture as
//! `python/compile/model.py`, computed with a per-token KV-cache state
//! machine.
//!
//! Crucially, *compression and decompression share this exact code path*
//! (one `advance` per token), so the probability streams on both sides are
//! bit-identical by construction. Numerics agree with the PJRT/XLA
//! executor to ~1e-4 (different reduction orders), which is why containers
//! record which executor produced them.

use crate::lm::config::{LmConfig, MAX_CONTEXT, VOCAB};
use crate::lm::weights::Weights;
use crate::Result;

/// GELU (tanh approximation — matches `jax.nn.gelu(approximate=True)`).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// y += x @ w, with x: [d_in], w: [d_in, d_out] row-major.
#[inline]
fn matvec_acc(x: &[f32], w: &[f32], y: &mut [f32]) {
    let d_out = y.len();
    debug_assert_eq!(x.len() * d_out, w.len());
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * d_out..(i + 1) * d_out];
        for j in 0..d_out {
            y[j] += xi * row[j];
        }
    }
}

fn matvec(x: &[f32], w: &[f32], d_out: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; d_out];
    matvec_acc(x, w, &mut y);
    y
}

fn rmsnorm(x: &[f32], gain: &[f32]) -> Vec<f32> {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    x.iter().zip(gain).map(|(v, g)| v * inv * g).collect()
}

/// Per-lane incremental state: the KV cache and the current position.
pub struct LaneState {
    /// [layer][kind(k=0,v=1)][pos * d_model ..]
    kv: Vec<f32>,
    pos: usize,
    n_layers: usize,
    d_model: usize,
    max_len: usize,
}

impl LaneState {
    pub fn new(cfg: &LmConfig, max_len: usize) -> Self {
        assert!(max_len <= MAX_CONTEXT);
        LaneState {
            kv: vec![0.0; cfg.n_layers * 2 * max_len * cfg.d_model],
            pos: 0,
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            max_len,
        }
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    #[inline]
    fn kv_slice(&self, layer: usize, kind: usize, pos: usize) -> std::ops::Range<usize> {
        let base = ((layer * 2 + kind) * self.max_len + pos) * self.d_model;
        base..base + self.d_model
    }
}

/// The model: config + weights, plus precomputed ALiBi slopes.
pub struct NativeModel {
    pub cfg: &'static LmConfig,
    weights: Weights,
    slopes: Vec<f32>,
}

impl NativeModel {
    pub fn new(cfg: &'static LmConfig, weights: Weights) -> Self {
        let slopes = (0..cfg.n_heads).map(|h| cfg.alibi_slope(h)).collect();
        NativeModel { cfg, weights, slopes }
    }

    /// Feed one token; returns the next-token logits `[VOCAB]` and advances
    /// the lane state. This single routine backs compression, decompression
    /// and generation — bit-exact across all of them by construction.
    pub fn advance(&self, st: &mut LaneState, token: u32) -> Result<Vec<f32>> {
        if st.pos >= st.max_len {
            anyhow::bail!("lane overflow: pos {} >= max {}", st.pos, st.max_len);
        }
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.d_head();
        let pos = st.pos;
        let embed = &self.weights.get("embed").data;
        let mut x: Vec<f32> = embed[token as usize * d..(token as usize + 1) * d].to_vec();

        for layer in 0..self.cfg.n_layers {
            let p = format!("layer{layer:02}.");
            let hn = rmsnorm(&x, &self.weights.get(&format!("{p}attn_norm")).data);
            let q = matvec(&hn, &self.weights.get(&format!("{p}wq")).data, d);
            let k = matvec(&hn, &self.weights.get(&format!("{p}wk")).data, d);
            let v = matvec(&hn, &self.weights.get(&format!("{p}wv")).data, d);
            let kr = st.kv_slice(layer, 0, pos);
            st.kv[kr].copy_from_slice(&k);
            let vr = st.kv_slice(layer, 1, pos);
            st.kv[vr].copy_from_slice(&v);

            // Attention per head over cache positions 0..=pos with ALiBi.
            let scale = 1.0 / (dh as f32).sqrt();
            let mut attn_out = vec![0.0f32; d];
            for head in 0..h {
                let slope = self.slopes[head];
                let qh = &q[head * dh..(head + 1) * dh];
                // scores
                let mut scores = Vec::with_capacity(pos + 1);
                let mut max_s = f32::NEG_INFINITY;
                for j in 0..=pos {
                    let kj = &st.kv[st.kv_slice(layer, 0, j)][head * dh..(head + 1) * dh];
                    let mut dot = 0.0f32;
                    for i in 0..dh {
                        dot += qh[i] * kj[i];
                    }
                    let s = dot * scale - slope * (pos - j) as f32;
                    max_s = max_s.max(s);
                    scores.push(s);
                }
                let mut denom = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - max_s).exp();
                    denom += *s;
                }
                let inv = 1.0 / denom;
                let out = &mut attn_out[head * dh..(head + 1) * dh];
                for (j, &w) in scores.iter().enumerate() {
                    let vj = &st.kv[st.kv_slice(layer, 1, j)][head * dh..(head + 1) * dh];
                    let wj = w * inv;
                    for i in 0..dh {
                        out[i] += wj * vj[i];
                    }
                }
            }
            matvec_acc(&attn_out, &self.weights.get(&format!("{p}wo")).data, &mut x);

            let hn = rmsnorm(&x, &self.weights.get(&format!("{p}mlp_norm")).data);
            let mut ff = matvec(&hn, &self.weights.get(&format!("{p}w1")).data, self.cfg.d_ff());
            for v in ff.iter_mut() {
                *v = gelu(*v);
            }
            matvec_acc(&ff, &self.weights.get(&format!("{p}w2")).data, &mut x);
        }

        let xn = rmsnorm(&x, &self.weights.get("final_norm").data);
        // Weight-tied head: logits[v] = dot(xn, embed[v]).
        let mut logits = vec![0.0f32; VOCAB];
        for (v, lo) in logits.iter_mut().enumerate() {
            let row = &embed[v * d..(v + 1) * d];
            let mut dot = 0.0f32;
            for i in 0..d {
                dot += xn[i] * row[i];
            }
            *lo = dot;
        }
        st.pos += 1;
        Ok(logits)
    }
}

/// Native executor: a [`NativeModel`] plus a pool of lanes.
pub struct NativeExecutor {
    model: NativeModel,
    lanes: Vec<LaneState>,
}

impl NativeExecutor {
    pub fn new(cfg: &'static LmConfig, weights: Weights, n_lanes: usize) -> Self {
        let model = NativeModel::new(cfg, weights);
        let lanes = (0..n_lanes).map(|_| LaneState::new(cfg, MAX_CONTEXT)).collect();
        NativeExecutor { model, lanes }
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }
}

impl crate::lm::executor::LmExecutor for NativeExecutor {
    fn config(&self) -> &'static LmConfig {
        self.model.cfg
    }

    fn kind(&self) -> crate::lm::executor::ExecutorKind {
        crate::lm::executor::ExecutorKind::Native
    }

    fn lanes(&self) -> usize {
        self.lanes.len()
    }

    fn reset(&mut self) {
        for l in self.lanes.iter_mut() {
            l.reset();
        }
    }

    fn step(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
        if tokens.len() != self.lanes.len() {
            anyhow::bail!("step expects {} lane tokens, got {}", self.lanes.len(), tokens.len());
        }
        let mut out = Vec::with_capacity(self.lanes.len() * VOCAB);
        for (lane, &tok) in self.lanes.iter_mut().zip(tokens) {
            out.extend(self.model.advance(lane, tok)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;
    use crate::lm::executor::LmExecutor;
    use crate::tokenizer::vocab::BOS;

    fn softmax(logits: &[f32]) -> Vec<f32> {
        let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
        let s: f32 = e.iter().sum();
        e.into_iter().map(|x| x / s).collect()
    }

    #[test]
    fn advance_is_deterministic_and_replayable() {
        let cfg = by_name("nano").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 1));
        let tokens = [BOS, 72, 101, 108, 108, 111];
        let mut st1 = LaneState::new(cfg, 16);
        let run1: Vec<Vec<f32>> =
            tokens.iter().map(|&t| model.advance(&mut st1, t).unwrap()).collect();
        let mut st2 = LaneState::new(cfg, 16);
        let run2: Vec<Vec<f32>> =
            tokens.iter().map(|&t| model.advance(&mut st2, t).unwrap()).collect();
        assert_eq!(run1, run2, "bit-exact replay");
    }

    #[test]
    fn logits_are_finite_and_distribution_valid() {
        let cfg = by_name("tiny").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 2));
        let mut st = LaneState::new(cfg, 32);
        for &t in &[BOS, 10, 200, 65, 0, 255] {
            let logits = model.advance(&mut st, t).unwrap();
            assert_eq!(logits.len(), VOCAB);
            assert!(logits.iter().all(|x| x.is_finite()));
            let p = softmax(&logits);
            let sum: f32 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn prefix_property_holds() {
        // Logits after feeding prefix P are identical regardless of what
        // would come later (trivially true for the incremental formulation,
        // but this guards against accidental lookahead bugs).
        let cfg = by_name("nano").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 3));
        let mut a = LaneState::new(cfg, 16);
        let la = model.advance(&mut a, BOS).unwrap();
        let mut b = LaneState::new(cfg, 16);
        let lb = model.advance(&mut b, BOS).unwrap();
        model.advance(&mut b, 42).unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn lane_overflow_rejected() {
        let cfg = by_name("nano").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 4));
        let mut st = LaneState::new(cfg, 4);
        for _ in 0..4 {
            model.advance(&mut st, 65).unwrap();
        }
        assert!(model.advance(&mut st, 65).is_err());
    }

    #[test]
    fn executor_steps_all_lanes() {
        let cfg = by_name("nano").unwrap();
        let mut ex = NativeExecutor::new(cfg, Weights::random(cfg, 5), 3);
        let out = ex.step(&[BOS, BOS, BOS]).unwrap();
        assert_eq!(out.len(), 3 * VOCAB);
        // Same token in every lane from fresh state -> identical logits.
        assert_eq!(out[..VOCAB], out[VOCAB..2 * VOCAB]);
        assert!(ex.step(&[1, 2]).is_err());
    }

    #[test]
    fn context_changes_prediction() {
        let cfg = by_name("tiny").unwrap();
        let model = NativeModel::new(cfg, Weights::random(cfg, 6));
        let mut a = LaneState::new(cfg, 8);
        model.advance(&mut a, BOS).unwrap();
        let la = model.advance(&mut a, 65).unwrap();
        let mut b = LaneState::new(cfg, 8);
        model.advance(&mut b, BOS).unwrap();
        let lb = model.advance(&mut b, 90).unwrap();
        assert_ne!(la, lb, "different contexts must give different logits");
    }
}
