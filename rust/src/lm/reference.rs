//! Frozen pre-refactor ("seed") implementation of the native transformer.
//!
//! This module is the **golden reference** for the resolved-plan/batched
//! engine in [`crate::lm::native`]:
//!
//! * `tests/golden_logits.rs` asserts that [`crate::lm::native::NativeModel::advance_batch`]
//!   reproduces [`ReferenceModel::advance`] **bit for bit** on every model
//!   tier, which is what guarantees containers compressed by the seed code
//!   still decompress under the refactored engine.
//! * `benches/runtime.rs` reports the batched engine's tokens/sec speedup
//!   over this baseline in `BENCH_runtime.json`.
//!
//! DO NOT OPTIMIZE OR "CLEAN UP" THIS FILE — its entire value is that it
//! never changes. The string-keyed weight lookups and per-token heap
//! allocations are intentional: they are exactly what the refactor removed,
//! and exactly what the seed binary executed.

use crate::lm::config::{LmConfig, MAX_CONTEXT, VOCAB};
use crate::lm::weights::Weights;
use crate::Result;

/// GELU (tanh approximation) — identical constant and expression to the
/// seed (and to `lm::native::gelu`).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// y += x @ w, with x: [d_in], w: [d_in, d_out] row-major.
#[inline]
fn matvec_acc(x: &[f32], w: &[f32], y: &mut [f32]) {
    let d_out = y.len();
    debug_assert_eq!(x.len() * d_out, w.len());
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * d_out..(i + 1) * d_out];
        for j in 0..d_out {
            y[j] += xi * row[j];
        }
    }
}

fn matvec(x: &[f32], w: &[f32], d_out: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; d_out];
    matvec_acc(x, w, &mut y);
    y
}

fn rmsnorm(x: &[f32], gain: &[f32]) -> Vec<f32> {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    x.iter().zip(gain).map(|(v, g)| v * inv * g).collect()
}

/// Seed `LaneState`: the KV cache and the current position.
pub struct ReferenceLane {
    /// [layer][kind(k=0,v=1)][pos * d_model ..]
    kv: Vec<f32>,
    pos: usize,
    d_model: usize,
    max_len: usize,
}

impl ReferenceLane {
    pub fn new(cfg: &LmConfig, max_len: usize) -> Self {
        assert!(max_len <= MAX_CONTEXT);
        ReferenceLane {
            kv: vec![0.0; cfg.n_layers * 2 * max_len * cfg.d_model],
            pos: 0,
            d_model: cfg.d_model,
            max_len,
        }
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    #[inline]
    fn kv_slice(&self, layer: usize, kind: usize, pos: usize) -> std::ops::Range<usize> {
        let base = ((layer * 2 + kind) * self.max_len + pos) * self.d_model;
        base..base + self.d_model
    }
}

/// Seed `NativeModel`: config + string-keyed weights + ALiBi slopes.
pub struct ReferenceModel {
    pub cfg: &'static LmConfig,
    weights: Weights,
    slopes: Vec<f32>,
}

impl ReferenceModel {
    pub fn new(cfg: &'static LmConfig, weights: Weights) -> Self {
        let slopes = (0..cfg.n_heads).map(|h| cfg.alibi_slope(h)).collect();
        ReferenceModel { cfg, weights, slopes }
    }

    /// The seed `advance`, verbatim: one token in, `[VOCAB]` logits out,
    /// with a `format!`-keyed HashMap lookup per weight tensor and fresh
    /// `Vec` allocations for every intermediate.
    pub fn advance(&self, st: &mut ReferenceLane, token: u32) -> Result<Vec<f32>> {
        if st.pos >= st.max_len {
            anyhow::bail!("lane overflow: pos {} >= max {}", st.pos, st.max_len);
        }
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.d_head();
        let pos = st.pos;
        let embed = &self.weights.get("embed").data;
        let mut x: Vec<f32> = embed[token as usize * d..(token as usize + 1) * d].to_vec();

        for layer in 0..self.cfg.n_layers {
            let p = format!("layer{layer:02}.");
            let hn = rmsnorm(&x, &self.weights.get(&format!("{p}attn_norm")).data);
            let q = matvec(&hn, &self.weights.get(&format!("{p}wq")).data, d);
            let k = matvec(&hn, &self.weights.get(&format!("{p}wk")).data, d);
            let v = matvec(&hn, &self.weights.get(&format!("{p}wv")).data, d);
            let kr = st.kv_slice(layer, 0, pos);
            st.kv[kr].copy_from_slice(&k);
            let vr = st.kv_slice(layer, 1, pos);
            st.kv[vr].copy_from_slice(&v);

            // Attention per head over cache positions 0..=pos with ALiBi.
            let scale = 1.0 / (dh as f32).sqrt();
            let mut attn_out = vec![0.0f32; d];
            for head in 0..h {
                let slope = self.slopes[head];
                let qh = &q[head * dh..(head + 1) * dh];
                // scores
                let mut scores = Vec::with_capacity(pos + 1);
                let mut max_s = f32::NEG_INFINITY;
                for j in 0..=pos {
                    let kj = &st.kv[st.kv_slice(layer, 0, j)][head * dh..(head + 1) * dh];
                    let mut dot = 0.0f32;
                    for i in 0..dh {
                        dot += qh[i] * kj[i];
                    }
                    let s = dot * scale - slope * (pos - j) as f32;
                    max_s = max_s.max(s);
                    scores.push(s);
                }
                let mut denom = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - max_s).exp();
                    denom += *s;
                }
                let inv = 1.0 / denom;
                let out = &mut attn_out[head * dh..(head + 1) * dh];
                for (j, &w) in scores.iter().enumerate() {
                    let vj = &st.kv[st.kv_slice(layer, 1, j)][head * dh..(head + 1) * dh];
                    let wj = w * inv;
                    for i in 0..dh {
                        out[i] += wj * vj[i];
                    }
                }
            }
            matvec_acc(&attn_out, &self.weights.get(&format!("{p}wo")).data, &mut x);

            let hn = rmsnorm(&x, &self.weights.get(&format!("{p}mlp_norm")).data);
            let mut ff = matvec(&hn, &self.weights.get(&format!("{p}w1")).data, self.cfg.d_ff());
            for v in ff.iter_mut() {
                *v = gelu(*v);
            }
            matvec_acc(&ff, &self.weights.get(&format!("{p}w2")).data, &mut x);
        }

        let xn = rmsnorm(&x, &self.weights.get("final_norm").data);
        // Weight-tied head: logits[v] = dot(xn, embed[v]).
        let mut logits = vec![0.0f32; VOCAB];
        for (v, lo) in logits.iter_mut().enumerate() {
            let row = &embed[v * d..(v + 1) * d];
            let mut dot = 0.0f32;
            for i in 0..d {
                dot += xn[i] * row[i];
            }
            *lo = dot;
        }
        st.pos += 1;
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;
    use crate::tokenizer::vocab::BOS;

    #[test]
    fn reference_is_deterministic() {
        let cfg = by_name("nano").unwrap();
        let model = ReferenceModel::new(cfg, Weights::random(cfg, 1));
        let mut a = ReferenceLane::new(cfg, 8);
        let mut b = ReferenceLane::new(cfg, 8);
        for &t in &[BOS, 72, 101] {
            assert_eq!(model.advance(&mut a, t).unwrap(), model.advance(&mut b, t).unwrap());
        }
        assert_eq!(a.pos(), 3);
        a.reset();
        assert_eq!(a.pos(), 0);
    }
}
