//! `.lmz` weights loader — mirror of `python/compile/weights.py` — plus the
//! [`ResolvedPlan`] that turns the string-keyed tensor bundle into direct
//! indices for the forward pass.
//!
//! The hot path contract: `Weights::get(name)` (format! + hash + map
//! lookup) exists for loaders, tools and the frozen reference
//! implementation only. The engine resolves every tensor ONCE at model
//! load into a [`ResolvedPlan`] and thereafter reaches weight data through
//! [`ResolvedPlan::data`] — a bare slice index.
//!
//! The plan holds the bundle behind an `Arc<Weights>`, so any number of
//! engine replicas (coordinator workers, pool threads, samplers) share ONE
//! copy of the tensors: replicating an executor costs KV-cache + scratch
//! memory only, never a second copy of the model.

use crate::lm::config::{param_spec, LmConfig};
use crate::util::read_u32_le;
use crate::Result;
use std::collections::HashMap;
use std::sync::Arc;

pub const WEIGHTS_MAGIC: u32 = 0x575A_4D4C; // "LMZW"
pub const WEIGHTS_VERSION: u16 = 1;

/// A named tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// A full parameter bundle for one model, in canonical spec order.
#[derive(Clone, Debug)]
pub struct Weights {
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl Weights {
    /// Parse from bytes and validate against the model's parameter spec.
    pub fn from_bytes(data: &[u8], cfg: &LmConfig) -> Result<Weights> {
        if data.len() < 8 {
            anyhow::bail!("weights file too short");
        }
        if read_u32_le(data, 0) != WEIGHTS_MAGIC {
            anyhow::bail!("bad weights magic");
        }
        let version = u16::from_le_bytes([data[4], data[5]]);
        if version != WEIGHTS_VERSION {
            anyhow::bail!("unsupported weights version {version}");
        }
        let count = u16::from_le_bytes([data[6], data[7]]) as usize;
        let mut pos = 8usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            if pos >= data.len() {
                anyhow::bail!("truncated weights file");
            }
            let nlen = data[pos] as usize;
            pos += 1;
            let name = String::from_utf8(data[pos..pos + nlen].to_vec())?;
            pos += nlen;
            let ndim = data[pos] as usize;
            pos += 1;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32_le(data, pos) as usize);
                pos += 4;
            }
            let n: usize = shape.iter().product();
            if pos + n * 4 > data.len() {
                anyhow::bail!("truncated tensor data for '{name}'");
            }
            let mut values = Vec::with_capacity(n);
            for i in 0..n {
                values.push(f32::from_le_bytes(data[pos + i * 4..pos + i * 4 + 4].try_into()?));
            }
            pos += n * 4;
            tensors.push(Tensor { name, shape, data: values });
        }
        // Validate against the canonical spec (order, names, shapes).
        let spec = param_spec(cfg);
        if spec.len() != tensors.len() {
            anyhow::bail!("weights tensor count {} != spec {}", tensors.len(), spec.len());
        }
        for ((name, shape), t) in spec.iter().zip(&tensors) {
            if *name != t.name {
                anyhow::bail!("tensor order mismatch: '{}' vs expected '{name}'", t.name);
            }
            if *shape != t.shape {
                anyhow::bail!("tensor '{}' shape {:?} != expected {:?}", t.name, t.shape, shape);
            }
        }
        let index = tensors.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        Ok(Weights { tensors, index })
    }

    pub fn load(path: &std::path::Path, cfg: &LmConfig) -> Result<Weights> {
        let data = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading weights {}: {e}", path.display()))?;
        Self::from_bytes(&data, cfg)
    }

    /// Tensor by name (panics on unknown name — internal use after validate).
    /// Cold paths only; the engine goes through [`ResolvedPlan`] +
    /// [`Weights::data`] instead.
    pub fn get(&self, name: &str) -> &Tensor {
        &self.tensors[self.index[name]]
    }

    /// Tensor index by name (used once per model load by
    /// [`ResolvedPlan::build`]).
    pub fn tensor_index(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("weights have no tensor named '{name}'"))
    }

    /// Raw data of the tensor at a resolved index — the engine's only
    /// weight accessor (no strings, no hashing, no map).
    #[inline]
    pub fn data(&self, idx: usize) -> &[f32] {
        &self.tensors[idx].data
    }

    /// Serialize back to `.lmz` bytes (round-trip support + test fixtures).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&WEIGHTS_MAGIC.to_le_bytes());
        out.extend_from_slice(&WEIGHTS_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u16).to_le_bytes());
        for t in &self.tensors {
            out.push(t.name.len() as u8);
            out.extend_from_slice(t.name.as_bytes());
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Deterministically-random weights for tests (no trained artifacts
    /// needed): same init family as python's `init_params`.
    pub fn random(cfg: &LmConfig, seed: u64) -> Weights {
        let mut rng = crate::util::Pcg64::seeded(seed);
        let mut tensors = Vec::new();
        for (name, shape) in param_spec(cfg) {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with("norm") {
                vec![1.0; n]
            } else {
                let scale = if name == "embed" {
                    0.02
                } else {
                    1.0 / (shape[0] as f32).sqrt()
                };
                (0..n)
                    .map(|_| {
                        // Box-Muller normal.
                        let u1 = rng.gen_f64().max(1e-12);
                        let u2 = rng.gen_f64();
                        let z = (-2.0 * u1.ln()).sqrt()
                            * (2.0 * std::f64::consts::PI * u2).cos();
                        (z as f32) * scale
                    })
                    .collect()
            };
            tensors.push(Tensor { name, shape, data });
        }
        let index = tensors.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        Weights { tensors, index }
    }
}

/// Direct tensor indices for one transformer layer — no string keys.
#[derive(Clone, Copy, Debug)]
pub struct LayerPlan {
    pub attn_norm: usize,
    pub mlp_norm: usize,
    pub wq: usize,
    pub wk: usize,
    pub wv: usize,
    pub wo: usize,
    pub w1: usize,
    pub w2: usize,
}

/// Resolved-weight execution plan: every tensor the forward pass touches,
/// resolved from string keys to `tensors[...]` indices once at model load,
/// plus a shared handle to the bundle itself. `NativeModel::advance_batch`
/// performs zero string formatting, hashing or map lookups per token — it
/// walks this plan and indexes [`ResolvedPlan::data`] directly.
///
/// Cloning a plan clones the `Arc`, not the tensors: every replica built
/// from the same bundle reads the same weight memory.
#[derive(Clone, Debug)]
pub struct ResolvedPlan {
    weights: Arc<Weights>,
    pub embed: usize,
    pub final_norm: usize,
    pub layers: Vec<LayerPlan>,
}

impl ResolvedPlan {
    /// Resolve against a validated weight bundle. Shape errors cannot occur
    /// here (the bundle was checked against `param_spec` at load), but a
    /// missing name is still reported rather than panicking.
    pub fn build(weights: Arc<Weights>, cfg: &LmConfig) -> Result<ResolvedPlan> {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layer{i:02}.");
            layers.push(LayerPlan {
                attn_norm: weights.tensor_index(&format!("{p}attn_norm"))?,
                mlp_norm: weights.tensor_index(&format!("{p}mlp_norm"))?,
                wq: weights.tensor_index(&format!("{p}wq"))?,
                wk: weights.tensor_index(&format!("{p}wk"))?,
                wv: weights.tensor_index(&format!("{p}wv"))?,
                wo: weights.tensor_index(&format!("{p}wo"))?,
                w1: weights.tensor_index(&format!("{p}w1"))?,
                w2: weights.tensor_index(&format!("{p}w2"))?,
            });
        }
        let embed = weights.tensor_index("embed")?;
        let final_norm = weights.tensor_index("final_norm")?;
        Ok(ResolvedPlan { weights, embed, final_norm, layers })
    }

    /// The shared weight bundle this plan indexes into.
    pub fn weights(&self) -> &Arc<Weights> {
        &self.weights
    }

    /// Raw data of the tensor at a resolved index — the engine's only
    /// weight accessor (no strings, no hashing, no map).
    #[inline]
    pub fn data(&self, idx: usize) -> &[f32] {
        self.weights.data(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;

    #[test]
    fn random_weights_match_spec() {
        let cfg = by_name("tiny").unwrap();
        let w = Weights::random(cfg, 1);
        assert_eq!(w.tensors.len(), param_spec(cfg).len());
        assert_eq!(w.get("embed").shape, vec![crate::lm::VOCAB, cfg.d_model]);
    }

    #[test]
    fn bytes_roundtrip() {
        let cfg = by_name("nano").unwrap();
        let w = Weights::random(cfg, 2);
        let bytes = w.to_bytes();
        let w2 = Weights::from_bytes(&bytes, cfg).unwrap();
        for (a, b) in w.tensors.iter().zip(&w2.tensors) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn wrong_model_rejected() {
        let nano = by_name("nano").unwrap();
        let tiny = by_name("tiny").unwrap();
        let bytes = Weights::random(nano, 3).to_bytes();
        assert!(Weights::from_bytes(&bytes, tiny).is_err());
    }

    #[test]
    fn resolved_plan_matches_string_lookups() {
        let cfg = by_name("medium").unwrap();
        let w = Arc::new(Weights::random(cfg, 5));
        let plan = ResolvedPlan::build(w.clone(), cfg).unwrap();
        assert_eq!(plan.layers.len(), cfg.n_layers);
        assert_eq!(plan.data(plan.embed), &w.get("embed").data[..]);
        assert_eq!(plan.data(plan.final_norm), &w.get("final_norm").data[..]);
        for (i, lp) in plan.layers.iter().enumerate() {
            let p = format!("layer{i:02}.");
            assert_eq!(plan.data(lp.wq), &w.get(&format!("{p}wq")).data[..]);
            assert_eq!(plan.data(lp.w2), &w.get(&format!("{p}w2")).data[..]);
            assert_eq!(plan.data(lp.attn_norm), &w.get(&format!("{p}attn_norm")).data[..]);
        }
    }

    #[test]
    fn resolved_plans_share_one_bundle() {
        // Two plans built from one Arc alias the same tensor memory: the
        // replica-pool contract (N executors, one copy of the weights).
        let cfg = by_name("nano").unwrap();
        let w = Arc::new(Weights::random(cfg, 6));
        let a = ResolvedPlan::build(w.clone(), cfg).unwrap();
        let b = ResolvedPlan::build(w.clone(), cfg).unwrap();
        assert!(std::ptr::eq(a.data(a.embed).as_ptr(), b.data(b.embed).as_ptr()));
        assert_eq!(Arc::strong_count(&w), 3);
    }

    #[test]
    fn corrupt_rejected() {
        let cfg = by_name("nano").unwrap();
        let mut bytes = Weights::random(cfg, 4).to_bytes();
        bytes[0] ^= 0xFF;
        assert!(Weights::from_bytes(&bytes, cfg).is_err());
        assert!(Weights::from_bytes(&[1, 2, 3], cfg).is_err());
    }
}
