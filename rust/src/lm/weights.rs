//! `.lmz` weights loader — mirror of `python/compile/weights.py` — plus the
//! [`ResolvedPlan`] that turns the string-keyed tensor bundle into direct
//! indices for the forward pass.
//!
//! ## Dtype-aware weight stack
//!
//! Tensor payloads are no longer hardwired `Vec<f32>`: [`TensorData`] is a
//! per-tensor dtype enum. Two dtypes exist today:
//!
//! * `F32` — the trained parameters, bit-exact with the seed format.
//! * `I8` — symmetric int8 quantization with **per-output-row f32 scales**
//!   (`w ≈ q * scale[row]`). For the weight-tied `embed` tensor the output
//!   rows are its leading rows (one scale per vocab entry, shared by the
//!   embedding lookup and the logit head); for every projection matrix
//!   `[d_in, d_out]` they are the output columns (one scale per output
//!   feature). 1-D norm gains always stay f32.
//!
//! On disk this is the `.lmz` **v2** format: identical to v1 plus one dtype
//! byte per tensor (and a scale table for quantized tensors). v1 files
//! still load (as all-F32) and [`Weights::to_bytes`] round-trips both
//! versions byte-exactly.
//!
//! ## Precision is a contract
//!
//! Lossless decoding requires bit-identical logits on the compressor and
//! decompressor, so the *exact weight bytes* both ends hold are part of the
//! stream contract — not a serving detail. [`Weights::quantize`] is
//! deterministic (same f32 bundle in, same int8 bundle out, on any host)
//! and [`Weights::fingerprint`] hashes the serialized bundle so containers
//! can record which bytes produced them and decoders can refuse a
//! mismatch up front instead of failing CRC after decoding garbage.
//!
//! The hot path contract is unchanged: `Weights::get(name)` (format! +
//! hash + map lookup) exists for loaders, tools and the frozen reference
//! implementation only. The engine resolves every tensor ONCE at model
//! load into a [`ResolvedPlan`] and thereafter reaches weight data through
//! [`ResolvedPlan::view`] — a bare slice index returning a dtype-tagged
//! [`TensorView`].
//!
//! The plan holds the bundle behind an `Arc<Weights>`, so any number of
//! engine replicas (coordinator workers, pool threads, samplers) share ONE
//! copy of the tensors: replicating an executor costs KV-cache + scratch
//! memory only, never a second copy of the model.

use crate::lm::config::{param_spec, LmConfig};
use crate::lm::kernels::{KernelOptions, KernelTier, PanelF32, PanelI8, Panels};
use crate::util::{crc32, read_u32_le};
use crate::Result;
use std::collections::HashMap;
use std::sync::{Arc, OnceLock};

pub const WEIGHTS_MAGIC: u32 = 0x575A_4D4C; // "LMZW"
/// Original all-f32 format (no per-tensor dtype byte).
pub const WEIGHTS_VERSION_V1: u16 = 1;
/// Dtype-aware format: one dtype byte per tensor, optional scale table.
pub const WEIGHTS_VERSION_V2: u16 = 2;

/// On-disk dtype byte values (v2 format).
const DTYPE_F32: u8 = 0;
const DTYPE_I8: u8 = 1;

/// Symmetric int8 quantization range (±127; -128 is never emitted so the
/// grid is symmetric and `-q` is always representable).
const Q8_MAX: f32 = 127.0;

/// Weight-bundle precision — the contract recorded in container tags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F32,
    Int8,
}

impl Precision {
    /// Short tag used in container strings and CLI flags.
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "f32" => Precision::F32,
            "int8" | "i8" | "q8" => Precision::Int8,
            other => anyhow::bail!("unknown precision '{other}' (f32|int8)"),
        })
    }
}

/// One tensor's payload, tagged by dtype.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    /// Symmetric int8 with per-output-row f32 scales: the dequantized value
    /// of element `e` in output row `r` is `data[e] as f32 * scales[r]`.
    I8 { data: Vec<i8>, scales: Vec<f32> },
}

impl TensorData {
    /// Element count (independent of dtype).
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I8 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_f32(&self) -> bool {
        matches!(self, TensorData::F32(_))
    }

    pub fn precision(&self) -> Precision {
        match self {
            TensorData::F32(_) => Precision::F32,
            TensorData::I8 { .. } => Precision::Int8,
        }
    }

    /// The f32 payload, if this tensor is f32.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            TensorData::I8 { .. } => None,
        }
    }

    /// Borrowed dtype-tagged view (what the engine dispatches on).
    pub fn view(&self) -> TensorView<'_> {
        match self {
            TensorData::F32(v) => TensorView::F32(v),
            TensorData::I8 { data, scales } => TensorView::I8 { data, scales },
        }
    }

    /// Resident bytes of this payload (data + scale table).
    pub fn resident_bytes(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len() * 4,
            TensorData::I8 { data, scales } => data.len() + scales.len() * 4,
        }
    }
}

/// Legacy f32 access: lets pre-dtype call sites (the frozen
/// `lm::reference`, tests, tools) keep reading `tensor.data` as an f32
/// slice. Quantized tensors have no f32 payload — such access is a
/// programming error and panics with a pointer at the dtype-aware API.
impl std::ops::Deref for TensorData {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        match self {
            TensorData::F32(v) => v,
            TensorData::I8 { .. } => panic!(
                "f32 access to an int8-quantized tensor — use TensorData::view()/as_f32()"
            ),
        }
    }
}

/// Borrowed dtype-dispatched view of one tensor's payload.
#[derive(Clone, Copy, Debug)]
pub enum TensorView<'a> {
    F32(&'a [f32]),
    I8 { data: &'a [i8], scales: &'a [f32] },
}

/// A named tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: TensorData,
    /// Lazily-built interleaved-panel copy for the SIMD matmul kernels
    /// (2-D projection tensors only; built at most once per bundle, so
    /// every replica sharing an `Arc<Weights>` shares one panel copy).
    /// Never serialized and excluded from the fingerprint: panels are a
    /// deterministic function of `data`, not part of the `.lmz`
    /// contract.
    panels: OnceLock<Panels>,
}

impl Tensor {
    fn new(name: String, shape: Vec<usize>, data: TensorData) -> Tensor {
        Tensor { name, shape, data, panels: OnceLock::new() }
    }

    /// The panelized copy, if one has been built.
    pub fn panels(&self) -> Option<&Panels> {
        self.panels.get()
    }

    /// Build (once) and return the panelized copy of a 2-D tensor.
    pub fn ensure_panels(&self) -> &Panels {
        self.panels.get_or_init(|| {
            assert_eq!(self.shape.len(), 2, "panels are for 2-D projection tensors");
            let (d_in, d_out) = (self.shape[0], self.shape[1]);
            match &self.data {
                TensorData::F32(v) => Panels::F32(PanelF32::build(v, d_in, d_out)),
                TensorData::I8 { data, .. } => Panels::I8(PanelI8::build(data, d_in, d_out)),
            }
        })
    }

    /// Resident bytes of this tensor: payload + scale table + any
    /// panelized copy (panels roughly double a projection's footprint,
    /// and the autoscaler's paging signals must see that).
    pub fn resident_bytes(&self) -> usize {
        self.data.resident_bytes() + self.panels.get().map_or(0, Panels::resident_bytes)
    }
}

/// Whether a 2-D tensor's quantization scales run along its leading rows:
/// the weight-tied `embed` is consumed row-wise (embedding lookup + logit
/// head), every projection `[d_in, d_out]` column-wise. The single source
/// of truth for the scale axis — `quantize_2d` (producer) and the loader's
/// validation (consumer) both derive from it.
fn scales_along_rows(name: &str) -> bool {
    name == "embed"
}

/// Expected scale-table length for a 2-D tensor.
fn scales_len(name: &str, shape: &[usize]) -> usize {
    if scales_along_rows(name) {
        shape[0]
    } else {
        shape[1]
    }
}

/// A full parameter bundle for one model, in canonical spec order.
#[derive(Clone, Debug)]
pub struct Weights {
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
    /// On-disk format version this bundle serializes as (v1 for all-f32
    /// bundles created before quantization, v2 once any tensor is i8 or
    /// the bundle was loaded from a v2 file).
    version: u16,
    /// Lazily-computed content fingerprint (serializing a bundle to hash
    /// it is not free, and every replica of a shared `Arc<Weights>` asks
    /// for the same value). Tensors are treated as frozen after
    /// construction.
    fingerprint: OnceLock<u32>,
}

impl Weights {
    /// Parse from bytes and validate against the model's parameter spec.
    /// Accepts v1 (all-f32) and v2 (per-tensor dtype) files.
    pub fn from_bytes(data: &[u8], cfg: &LmConfig) -> Result<Weights> {
        if data.len() < 8 {
            anyhow::bail!("weights file too short");
        }
        if read_u32_le(data, 0) != WEIGHTS_MAGIC {
            anyhow::bail!("bad weights magic");
        }
        let version = u16::from_le_bytes([data[4], data[5]]);
        if version != WEIGHTS_VERSION_V1 && version != WEIGHTS_VERSION_V2 {
            anyhow::bail!("unsupported weights version {version}");
        }
        let count = u16::from_le_bytes([data[6], data[7]]) as usize;
        let mut pos = 8usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            if pos >= data.len() {
                anyhow::bail!("truncated weights file");
            }
            let nlen = data[pos] as usize;
            pos += 1;
            if pos + nlen + 1 > data.len() {
                anyhow::bail!("truncated tensor header");
            }
            let name = String::from_utf8(data[pos..pos + nlen].to_vec())?;
            pos += nlen;
            let ndim = data[pos] as usize;
            pos += 1;
            if pos + ndim * 4 > data.len() {
                anyhow::bail!("truncated tensor shape for '{name}'");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32_le(data, pos) as usize);
                pos += 4;
            }
            let n: usize = shape.iter().product();
            let dtype = if version >= WEIGHTS_VERSION_V2 {
                if pos >= data.len() {
                    anyhow::bail!("truncated dtype byte for '{name}'");
                }
                let d = data[pos];
                pos += 1;
                d
            } else {
                DTYPE_F32
            };
            let payload = match dtype {
                DTYPE_F32 => {
                    if pos + n * 4 > data.len() {
                        anyhow::bail!("truncated tensor data for '{name}'");
                    }
                    let values: Vec<f32> = data[pos..pos + n * 4]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().expect("chunks_exact(4)")))
                        .collect();
                    pos += n * 4;
                    TensorData::F32(values)
                }
                DTYPE_I8 => {
                    if pos + 4 > data.len() {
                        anyhow::bail!("truncated scale table for '{name}'");
                    }
                    let ns = read_u32_le(data, pos) as usize;
                    pos += 4;
                    if pos + ns * 4 + n > data.len() {
                        anyhow::bail!("truncated tensor data for '{name}'");
                    }
                    let scales: Vec<f32> = data[pos..pos + ns * 4]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().expect("chunks_exact(4)")))
                        .collect();
                    pos += ns * 4;
                    let values: Vec<i8> = data[pos..pos + n].iter().map(|&b| b as i8).collect();
                    pos += n;
                    TensorData::I8 { data: values, scales }
                }
                other => anyhow::bail!("unknown dtype byte {other} for tensor '{name}'"),
            };
            tensors.push(Tensor::new(name, shape, payload));
        }
        // Validate against the canonical spec (order, names, shapes, and
        // per-dtype invariants).
        let spec = param_spec(cfg);
        if spec.len() != tensors.len() {
            anyhow::bail!("weights tensor count {} != spec {}", tensors.len(), spec.len());
        }
        for ((name, shape), t) in spec.iter().zip(&tensors) {
            if *name != t.name {
                anyhow::bail!("tensor order mismatch: '{}' vs expected '{name}'", t.name);
            }
            if *shape != t.shape {
                anyhow::bail!("tensor '{}' shape {:?} != expected {:?}", t.name, t.shape, shape);
            }
            if let TensorData::I8 { scales, .. } = &t.data {
                if t.shape.len() != 2 {
                    anyhow::bail!("tensor '{}' is int8 but not 2-D (norms stay f32)", t.name);
                }
                let want = scales_len(&t.name, &t.shape);
                if scales.len() != want {
                    anyhow::bail!(
                        "tensor '{}' has {} scales, expected {want}",
                        t.name,
                        scales.len()
                    );
                }
            }
        }
        let index = tensors.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        Ok(Weights { tensors, index, version, fingerprint: OnceLock::new() })
    }

    pub fn load(path: &std::path::Path, cfg: &LmConfig) -> Result<Weights> {
        let data = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading weights {}: {e}", path.display()))?;
        Self::from_bytes(&data, cfg)
    }

    /// Tensor by name (panics on unknown name — internal use after validate).
    /// Cold paths only; the engine goes through [`ResolvedPlan`] +
    /// [`Weights::view`] instead.
    pub fn get(&self, name: &str) -> &Tensor {
        &self.tensors[self.index[name]]
    }

    /// Tensor index by name (used once per model load by
    /// [`ResolvedPlan::build`]).
    pub fn tensor_index(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("weights have no tensor named '{name}'"))
    }

    /// Raw f32 data of the tensor at a resolved index. Panics if the tensor
    /// is quantized — f32-only consumers (norm gains, the frozen reference,
    /// PJRT upload) are guarded upstream; the dtype-generic engine path
    /// uses [`Weights::view`].
    #[inline]
    pub fn data(&self, idx: usize) -> &[f32] {
        &self.tensors[idx].data
    }

    /// Dtype-tagged view of the tensor at a resolved index — the engine's
    /// only weight accessor (no strings, no hashing, no map).
    #[inline]
    pub fn view(&self, idx: usize) -> TensorView<'_> {
        self.tensors[idx].data.view()
    }

    /// Bundle precision: `Int8` as soon as any tensor is quantized.
    pub fn precision(&self) -> Precision {
        if self.tensors.iter().all(|t| t.data.is_f32()) {
            Precision::F32
        } else {
            Precision::Int8
        }
    }

    /// Bytes of weight memory this bundle holds resident: payloads +
    /// scale tables + any panelized kernel copies (see
    /// [`Tensor::resident_bytes`]). With the panel layout enabled this
    /// roughly doubles the projection weights — the autoscaler/paging
    /// signals must not undercount that, and `ServerConfig` exposes a
    /// knob to disable panels on memory-constrained hosts.
    pub fn resident_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.resident_bytes()).sum()
    }

    /// Content fingerprint of the serialized bundle. Compressor and
    /// decompressor must hold byte-identical weights for lossless decode;
    /// quantized containers record this value so a mismatch is rejected
    /// with a clear error instead of surfacing as a CRC failure. Computed
    /// once per bundle (replicas sharing an `Arc<Weights>` all read the
    /// cached value).
    pub fn fingerprint(&self) -> u32 {
        *self.fingerprint.get_or_init(|| crc32(&self.to_bytes()))
    }

    /// [`Self::fingerprint`] in the 8-hex-digit spelling container tags
    /// and fleet paging diagnostics use (`{:08x}`), so logs, tags and
    /// reload-verify errors all render the same token.
    pub fn fingerprint_hex(&self) -> String {
        format!("{:08x}", self.fingerprint())
    }

    /// Serialize to `.lmz` bytes: v1 when the bundle is all-f32 and was not
    /// loaded from a v2 file (bit-exact with the seed format), v2 otherwise.
    /// Round-trips both formats byte-exactly through [`Weights::from_bytes`].
    pub fn to_bytes(&self) -> Vec<u8> {
        // Guard the u16 count field — silently truncating the tensor count
        // would produce a file that parses to a different (wrong) bundle.
        assert!(
            self.tensors.len() <= u16::MAX as usize,
            "tensor count {} overflows the u16 count field",
            self.tensors.len()
        );
        let v2 = self.version >= WEIGHTS_VERSION_V2
            || self.tensors.iter().any(|t| !t.data.is_f32());
        let version = if v2 { WEIGHTS_VERSION_V2 } else { WEIGHTS_VERSION_V1 };
        let mut out = Vec::new();
        out.extend_from_slice(&WEIGHTS_MAGIC.to_le_bytes());
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u16).to_le_bytes());
        for t in &self.tensors {
            out.push(t.name.len() as u8);
            out.extend_from_slice(t.name.as_bytes());
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            match &t.data {
                TensorData::F32(values) => {
                    if v2 {
                        out.push(DTYPE_F32);
                    }
                    for &v in values {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                TensorData::I8 { data, scales } => {
                    debug_assert!(v2);
                    out.push(DTYPE_I8);
                    out.extend_from_slice(&(scales.len() as u32).to_le_bytes());
                    for &s in scales {
                        out.extend_from_slice(&s.to_le_bytes());
                    }
                    out.extend(data.iter().map(|&q| q as u8));
                }
            }
        }
        out
    }

    /// Deterministic symmetric int8 quantization of every 2-D tensor
    /// (per-output-row scales; 1-D norm gains stay f32; already-quantized
    /// tensors pass through unchanged). Pure function of the input bytes —
    /// the same f32 bundle quantizes to the same int8 bundle on every
    /// host, which is what lets compressor and decompressor derive the
    /// shared contract independently from one `.lmz` v1 file.
    pub fn quantize(&self) -> Weights {
        let tensors: Vec<Tensor> = self
            .tensors
            .iter()
            .map(|t| {
                let data = match (&t.data, t.shape.len()) {
                    (TensorData::F32(values), 2) => {
                        quantize_2d(&t.name, &t.shape, values)
                    }
                    _ => t.data.clone(),
                };
                Tensor::new(t.name.clone(), t.shape.clone(), data)
            })
            .collect();
        let index = tensors.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        Weights { tensors, index, version: WEIGHTS_VERSION_V2, fingerprint: OnceLock::new() }
    }

    /// Deterministically-random weights for tests (no trained artifacts
    /// needed): same init family as python's `init_params`.
    pub fn random(cfg: &LmConfig, seed: u64) -> Weights {
        let mut rng = crate::util::Pcg64::seeded(seed);
        let mut tensors = Vec::new();
        for (name, shape) in param_spec(cfg) {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with("norm") {
                vec![1.0; n]
            } else {
                let scale = if name == "embed" {
                    0.02
                } else {
                    1.0 / (shape[0] as f32).sqrt()
                };
                (0..n)
                    .map(|_| {
                        // Box-Muller normal.
                        let u1 = rng.gen_f64().max(1e-12);
                        let u2 = rng.gen_f64();
                        let z = (-2.0 * u1.ln()).sqrt()
                            * (2.0 * std::f64::consts::PI * u2).cos();
                        (z as f32) * scale
                    })
                    .collect()
            };
            tensors.push(Tensor::new(name, shape, TensorData::F32(data)));
        }
        let index = tensors.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        Weights { tensors, index, version: WEIGHTS_VERSION_V1, fingerprint: OnceLock::new() }
    }
}

/// Quantize one 2-D f32 tensor to symmetric int8 with per-output-row
/// scales. `embed` is scaled along its leading rows, projections along
/// their output columns (see [`scales_len`]).
fn quantize_2d(name: &str, shape: &[usize], values: &[f32]) -> TensorData {
    let (rows, cols) = (shape[0], shape[1]);
    let by_row = scales_along_rows(name);
    let n_groups = if by_row { rows } else { cols };
    let mut scales = vec![0.0f32; n_groups];
    for (g, sg) in scales.iter_mut().enumerate() {
        let mut maxabs = 0.0f32;
        if by_row {
            for &v in &values[g * cols..(g + 1) * cols] {
                maxabs = maxabs.max(v.abs());
            }
        } else {
            for r in 0..rows {
                maxabs = maxabs.max(values[r * cols + g].abs());
            }
        }
        // An all-zero group keeps scale 1.0 (quantized values are 0).
        *sg = if maxabs == 0.0 { 1.0 } else { maxabs / Q8_MAX };
    }
    let data: Vec<i8> = values
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let g = if by_row { i / cols } else { i % cols };
            (v / scales[g]).round().clamp(-Q8_MAX, Q8_MAX) as i8
        })
        .collect();
    TensorData::I8 { data, scales }
}

/// Direct tensor indices for one transformer layer — no string keys.
#[derive(Clone, Copy, Debug)]
pub struct LayerPlan {
    pub attn_norm: usize,
    pub mlp_norm: usize,
    pub wq: usize,
    pub wk: usize,
    pub wv: usize,
    pub wo: usize,
    pub w1: usize,
    pub w2: usize,
}

/// Resolved-weight execution plan: every tensor the forward pass touches,
/// resolved from string keys to `tensors[...]` indices once at model load,
/// plus a shared handle to the bundle itself. `NativeModel::advance_batch`
/// performs zero string formatting, hashing or map lookups per token — it
/// walks this plan and indexes [`ResolvedPlan::view`] directly (the view
/// carries the dtype, so per-tensor kernel dispatch is a match on an
/// already-loaded enum, not a lookup).
///
/// Cloning a plan clones the `Arc`, not the tensors: every replica built
/// from the same bundle reads the same weight memory.
#[derive(Clone, Debug)]
pub struct ResolvedPlan {
    weights: Arc<Weights>,
    pub embed: usize,
    pub final_norm: usize,
    pub layers: Vec<LayerPlan>,
    /// Kernel dispatch tier, selected once here at model load — the
    /// engine never re-detects CPU features per call.
    tier: KernelTier,
    /// Whether matmuls may use the panelized weight copies. Gates access
    /// only: panels already built on the shared bundle (by another
    /// replica's plan) stay resident and counted either way.
    use_panels: bool,
}

impl ResolvedPlan {
    /// Resolve against a validated weight bundle with default kernel
    /// options (tier from `LLMZIP_FORCE_KERNEL` or CPU detection, panel
    /// layout enabled). Shape errors cannot occur here (the bundle was
    /// checked against `param_spec` at load), but a missing name is
    /// still reported rather than panicking.
    pub fn build(weights: Arc<Weights>, cfg: &LmConfig) -> Result<ResolvedPlan> {
        Self::build_with(weights, cfg, KernelOptions::default())
    }

    /// Resolve with explicit kernel options. An explicitly-requested
    /// tier the CPU cannot run is an error; with `opts.panels` the
    /// interleaved panel copies for every projection tensor are built
    /// here (deterministically, from the unchanged `.lmz` bytes) so the
    /// hot path never takes the `OnceLock` initialization branch.
    pub fn build_with(
        weights: Arc<Weights>,
        cfg: &LmConfig,
        opts: KernelOptions,
    ) -> Result<ResolvedPlan> {
        let tier = match opts.tier {
            Some(t) => {
                if !t.available() {
                    anyhow::bail!("kernel tier '{}' is not available on this CPU", t.as_str());
                }
                t
            }
            None => KernelTier::resolve()?,
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layer{i:02}.");
            layers.push(LayerPlan {
                attn_norm: weights.tensor_index(&format!("{p}attn_norm"))?,
                mlp_norm: weights.tensor_index(&format!("{p}mlp_norm"))?,
                wq: weights.tensor_index(&format!("{p}wq"))?,
                wk: weights.tensor_index(&format!("{p}wk"))?,
                wv: weights.tensor_index(&format!("{p}wv"))?,
                wo: weights.tensor_index(&format!("{p}wo"))?,
                w1: weights.tensor_index(&format!("{p}w1"))?,
                w2: weights.tensor_index(&format!("{p}w2"))?,
            });
        }
        let embed = weights.tensor_index("embed")?;
        let final_norm = weights.tensor_index("final_norm")?;
        if opts.panels {
            for lp in &layers {
                for idx in [lp.wq, lp.wk, lp.wv, lp.wo, lp.w1, lp.w2] {
                    weights.tensors[idx].ensure_panels();
                }
            }
        }
        Ok(ResolvedPlan { weights, embed, final_norm, layers, tier, use_panels: opts.panels })
    }

    /// The shared weight bundle this plan indexes into.
    pub fn weights(&self) -> &Arc<Weights> {
        &self.weights
    }

    /// The dispatch tier every kernel call under this plan uses.
    #[inline]
    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// Whether this plan's matmuls use the panel layout.
    pub fn panels_enabled(&self) -> bool {
        self.use_panels
    }

    /// The f32 panel for a resolved projection index, when panels are
    /// enabled, built, and the tensor is f32.
    #[inline]
    pub fn panel_f32(&self, idx: usize) -> Option<&PanelF32> {
        if !self.use_panels {
            return None;
        }
        self.weights.tensors[idx].panels().and_then(Panels::as_f32)
    }

    /// The i8 panel for a resolved projection index (see
    /// [`ResolvedPlan::panel_f32`]).
    #[inline]
    pub fn panel_i8(&self, idx: usize) -> Option<&PanelI8> {
        if !self.use_panels {
            return None;
        }
        self.weights.tensors[idx].panels().and_then(Panels::as_i8)
    }

    /// Raw f32 data of the tensor at a resolved index (norm gains and
    /// other always-f32 tensors; panics on quantized tensors).
    #[inline]
    pub fn data(&self, idx: usize) -> &[f32] {
        self.weights.data(idx)
    }

    /// Dtype-tagged view of the tensor at a resolved index — the engine's
    /// only weight accessor (no strings, no hashing, no map).
    #[inline]
    pub fn view(&self, idx: usize) -> TensorView<'_> {
        self.weights.view(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;

    #[test]
    fn random_weights_match_spec() {
        let cfg = by_name("tiny").unwrap();
        let w = Weights::random(cfg, 1);
        assert_eq!(w.tensors.len(), param_spec(cfg).len());
        assert_eq!(w.get("embed").shape, vec![crate::lm::VOCAB, cfg.d_model]);
    }

    #[test]
    fn bytes_roundtrip() {
        let cfg = by_name("nano").unwrap();
        let w = Weights::random(cfg, 2);
        let bytes = w.to_bytes();
        let w2 = Weights::from_bytes(&bytes, cfg).unwrap();
        for (a, b) in w.tensors.iter().zip(&w2.tensors) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
        }
        // All-f32 bundles keep serializing as v1, bit-exact with the seed
        // format (version field at offset 4).
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), WEIGHTS_VERSION_V1);
        assert_eq!(w2.to_bytes(), bytes, "v1 round-trips byte-exactly");
    }

    #[test]
    fn quantized_bytes_roundtrip_as_v2() {
        let cfg = by_name("nano").unwrap();
        let q = Weights::random(cfg, 2).quantize();
        let bytes = q.to_bytes();
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), WEIGHTS_VERSION_V2);
        let q2 = Weights::from_bytes(&bytes, cfg).unwrap();
        for (a, b) in q.tensors.iter().zip(&q2.tensors) {
            assert_eq!(a.data, b.data, "{}", a.name);
        }
        assert_eq!(q2.to_bytes(), bytes, "v2 round-trips byte-exactly");
        assert_eq!(q2.precision(), Precision::Int8);
    }

    #[test]
    fn quantize_is_deterministic_and_structured() {
        let cfg = by_name("tiny").unwrap();
        let w = Weights::random(cfg, 9);
        let a = w.quantize();
        let b = w.quantize();
        assert_eq!(a.to_bytes(), b.to_bytes(), "same input, same int8 bytes");
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), Weights::random(cfg, 10).quantize().fingerprint());
        // Quantizing a quantized bundle is a no-op.
        assert_eq!(a.quantize().to_bytes(), a.to_bytes());
        for t in &a.tensors {
            match (&t.data, t.shape.len()) {
                (TensorData::I8 { scales, .. }, 2) => {
                    assert_eq!(scales.len(), scales_len(&t.name, &t.shape), "{}", t.name);
                    assert!(scales.iter().all(|s| *s > 0.0));
                }
                (TensorData::F32(_), 1) => {} // norms stay f32
                other => panic!("{}: unexpected dtype/rank {other:?}", t.name),
            }
        }
        // Quantization ~halves resident weight bytes.
        let (f, q) = (w.resident_bytes(), a.resident_bytes());
        assert!(q * 3 < f * 2, "int8 {q} bytes vs f32 {f} bytes");
    }

    #[test]
    fn quantize_reconstruction_error_is_bounded() {
        let cfg = by_name("nano").unwrap();
        let w = Weights::random(cfg, 3);
        let q = w.quantize();
        let (wt, qt) = (w.get("embed"), q.get("embed"));
        let (TensorData::F32(orig), TensorData::I8 { data, scales }) = (&wt.data, &qt.data)
        else {
            panic!("dtypes");
        };
        let cols = wt.shape[1];
        for (i, &v) in orig.iter().enumerate() {
            let back = data[i] as f32 * scales[i / cols];
            // Symmetric quantization error is at most half a step.
            assert!((back - v).abs() <= scales[i / cols] * 0.5 + 1e-7, "elem {i}");
        }
    }

    #[test]
    fn wrong_model_rejected() {
        let nano = by_name("nano").unwrap();
        let tiny = by_name("tiny").unwrap();
        let bytes = Weights::random(nano, 3).to_bytes();
        assert!(Weights::from_bytes(&bytes, tiny).is_err());
        assert!(Weights::from_bytes(&Weights::random(nano, 3).quantize().to_bytes(), tiny)
            .is_err());
    }

    #[test]
    fn resolved_plan_matches_string_lookups() {
        let cfg = by_name("medium").unwrap();
        let w = Arc::new(Weights::random(cfg, 5));
        let plan = ResolvedPlan::build(w.clone(), cfg).unwrap();
        assert_eq!(plan.layers.len(), cfg.n_layers);
        assert_eq!(plan.data(plan.embed), &w.get("embed").data[..]);
        assert_eq!(plan.data(plan.final_norm), &w.get("final_norm").data[..]);
        for (i, lp) in plan.layers.iter().enumerate() {
            let p = format!("layer{i:02}.");
            assert_eq!(plan.data(lp.wq), &w.get(&format!("{p}wq")).data[..]);
            assert_eq!(plan.data(lp.w2), &w.get(&format!("{p}w2")).data[..]);
            assert_eq!(plan.data(lp.attn_norm), &w.get(&format!("{p}attn_norm")).data[..]);
        }
    }

    #[test]
    fn resolved_plans_share_one_bundle() {
        // Two plans built from one Arc alias the same tensor memory: the
        // replica-pool contract (N executors, one copy of the weights).
        let cfg = by_name("nano").unwrap();
        let w = Arc::new(Weights::random(cfg, 6));
        let a = ResolvedPlan::build(w.clone(), cfg).unwrap();
        let b = ResolvedPlan::build(w.clone(), cfg).unwrap();
        assert!(std::ptr::eq(a.data(a.embed).as_ptr(), b.data(b.embed).as_ptr()));
        assert_eq!(Arc::strong_count(&w), 3);
    }

    #[test]
    fn corrupt_rejected() {
        let cfg = by_name("nano").unwrap();
        let mut bytes = Weights::random(cfg, 4).to_bytes();
        bytes[0] ^= 0xFF;
        assert!(Weights::from_bytes(&bytes, cfg).is_err());
        assert!(Weights::from_bytes(&[1, 2, 3], cfg).is_err());
        // Truncations of a v2 file are rejected, never panic.
        let v2 = Weights::random(cfg, 4).quantize().to_bytes();
        for cut in [9usize, 40, v2.len() / 2, v2.len() - 1] {
            assert!(Weights::from_bytes(&v2[..cut], cfg).is_err(), "cut={cut}");
        }
        // Unknown dtype byte is rejected: corrupt the first tensor's dtype
        // (offset: 8 header + 1 + len("embed") + 1 + 2 dims * 4).
        let mut bad = v2.clone();
        let dt = 8 + 1 + 5 + 1 + 8;
        assert_eq!(bad[dt], 1, "expected embed's i8 dtype byte");
        bad[dt] = 7;
        assert!(Weights::from_bytes(&bad, cfg).is_err());
    }

    #[test]
    fn panels_are_shared_counted_and_gated() {
        let cfg = by_name("nano").unwrap();
        let w = Arc::new(Weights::random(cfg, 11));
        let bare = w.resident_bytes();
        // A panels-off plan builds nothing and exposes nothing.
        let off = ResolvedPlan::build_with(
            w.clone(),
            cfg,
            KernelOptions { tier: Some(KernelTier::Scalar), panels: false },
        )
        .unwrap();
        assert_eq!(w.resident_bytes(), bare);
        assert!(off.panel_f32(off.layers[0].wq).is_none());
        // A panels-on plan builds them once; resident_bytes grows by
        // roughly the projection payloads (all dims here are multiples
        // of the lane widths, so panels add exactly the projection
        // bytes), and a second plan reuses the same copies.
        let on = ResolvedPlan::build(w.clone(), cfg).unwrap();
        let with_panels = w.resident_bytes();
        assert!(with_panels > bare, "panels must be counted");
        let p1 = on.panel_f32(on.layers[0].wq).unwrap();
        let on2 = ResolvedPlan::build(w.clone(), cfg).unwrap();
        assert!(std::ptr::eq(p1, on2.panel_f32(on2.layers[0].wq).unwrap()));
        assert_eq!(w.resident_bytes(), with_panels, "no duplicate panel builds");
        // The panels-off plan still reports None even though the shared
        // bundle now holds built panels.
        assert!(off.panel_f32(off.layers[0].wq).is_none());
        // Quantized projections get i8 panels.
        let q = Arc::new(Weights::random(cfg, 11).quantize());
        let qp = ResolvedPlan::build(q.clone(), cfg).unwrap();
        assert!(qp.panel_i8(qp.layers[0].w1).is_some());
        assert!(qp.panel_f32(qp.layers[0].w1).is_none());
    }

    #[test]
    fn explicit_unavailable_tier_is_rejected() {
        let cfg = by_name("nano").unwrap();
        let w = Arc::new(Weights::random(cfg, 12));
        // Exactly one of avx2/neon can ever be available on one host.
        let foreign = if cfg!(target_arch = "x86_64") { KernelTier::Neon } else { KernelTier::Avx2 };
        let res = ResolvedPlan::build_with(
            w,
            cfg,
            KernelOptions { tier: Some(foreign), panels: true },
        );
        assert!(res.is_err());
    }

    #[test]
    fn precision_parse_roundtrip() {
        for p in [Precision::F32, Precision::Int8] {
            assert_eq!(Precision::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(Precision::parse("q8").unwrap(), Precision::Int8);
        assert!(Precision::parse("fp16").is_err());
    }

    #[test]
    #[should_panic(expected = "int8-quantized tensor")]
    fn legacy_f32_access_to_quantized_tensor_panics() {
        let cfg = by_name("nano").unwrap();
        let q = Weights::random(cfg, 5).quantize();
        let _ = &q.get("embed").data[0];
    }
}
