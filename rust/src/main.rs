//! `llmzip` — CLI for the LLM-compression reproduction.
//!
//! Subcommands are grouped by purpose:
//! * data:       `gen-corpus`, `gen-data`
//! * compression:`compress`, `decompress`, `ratio`
//! * service:    `serve`
//! * experiments:`table2`, `table3`, `table5`, `fig2`, `fig5`, `fig6`,
//!               `fig7`, `fig8`, `fig9`, `chunk-sweep`
//! * misc:       `models`, `analyze`
//!
//! The dependency set of this environment has no CLI crate; arguments are
//! parsed by the tiny hand-rolled [`cli`] module.

use llmzip::Result;

mod cli;
mod cmd;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "gen-corpus" => cmd::data::gen_corpus(rest),
        "gen-data" => cmd::data::gen_data(rest),
        "compress" => cmd::compress::compress(rest),
        "decompress" => cmd::compress::decompress(rest),
        "ratio" => cmd::compress::ratio(rest),
        "serve" => cmd::serve::serve(rest),
        "models" => cmd::models::run(rest),
        "analyze" => cmd::experiments::analyze(rest),
        "table2" => cmd::experiments::table2(rest),
        "table3" => cmd::experiments::table3(rest),
        "table5" => cmd::experiments::table5(rest),
        "fig2" => cmd::experiments::fig2(rest),
        "fig5" => cmd::experiments::fig5(rest),
        "fig6" => cmd::experiments::fig6(rest),
        "fig7" => cmd::experiments::fig7(rest),
        "fig8" => cmd::experiments::fig8(rest),
        "fig9" => cmd::experiments::fig9(rest),
        "chunk-sweep" => cmd::experiments::chunk_sweep(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `llmzip help`)"),
    }
}

fn print_usage() {
    println!(
        "llmzip — lossless compression of LLM-generated text via next-token prediction

USAGE: llmzip <COMMAND> [OPTIONS]

DATA
  gen-corpus  --out DIR [--bytes N] [--seed N]     write the procedural training corpora
  gen-data    --out DIR [--bytes N] [--model M]    sample the LLM-generated datasets

COMPRESSION (streaming: bounded memory; `-` means stdin/stdout)
  compress    --model M --in FILE|- --out FILE|- [--chunk N] [--executor pjrt|native]
              [--precision f32|int8]               int8 = quantized native weights
  decompress  --model M --in FILE|- --out FILE|- [--executor pjrt|native] [--precision P]
              [--range OFF:LEN]   decode only those original bytes — on a file,
                                  positioned reads fetch just the frames in range
  ratio       --model M --in FILE|- [--chunk N]    report the compression ratio

SERVICE
  serve       --model M [--port P] [--replicas N] [--min-replicas A --max-replicas B]
              [--precision f32|int8] [--no-steal] [--no-pool]  batched compression server
                                                   (a min/max range autoscales the pool;
                                                   speaks the multiplexed v2 protocol
                                                   with v1 auto-detected per connection)

EXPERIMENTS (regenerate the paper's tables and figures)
  table2 | table3 | table5 | fig2 | fig5 | fig6 | fig7 | fig8 | fig9 | chunk-sweep
  analyze     --in FILE                            n-gram + entropy report for a file

MISC
  models                                           list registered model variants
  models quantize --model M --in F32.lmz --out Q8.lmz   convert weights to int8 (.lmz v2)
  models gen      --model M --out FILE [--seed N]  write deterministic random weights"
    );
}
