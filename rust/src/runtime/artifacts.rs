//! Artifact store: locate, validate and lazily compile the AOT outputs.

use crate::lm::config::{self, LmConfig};
use crate::lm::weights::Weights;
use crate::Result;

use std::path::{Path, PathBuf};

/// Handle to an `artifacts/` directory.
///
/// The PJRT client is created LAZILY, on the first operation that actually
/// needs a device (compile / buffer upload): opening a store and loading
/// `.lmz` weights must keep working in builds where PJRT is unavailable
/// (the vendored `xla` stub), so the native executor can still be fed from
/// `artifacts/weights/` with no device runtime present.
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    /// Open the store at `root` (or `$LLMZIP_ARTIFACTS`, or `./artifacts`).
    pub fn open(root: Option<&str>) -> Result<ArtifactStore> {
        let root = match root {
            Some(r) => PathBuf::from(r),
            None => std::env::var("LLMZIP_ARTIFACTS")
                .map(PathBuf::from)
                .unwrap_or_else(|_| PathBuf::from("artifacts")),
        };
        if !root.is_dir() {
            anyhow::bail!(
                "artifacts directory {} not found — run `make artifacts` first",
                root.display()
            );
        }
        Ok(ArtifactStore { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The per-thread PJRT client (cheap handle clone, created on first
    /// use). Errors in PJRT-less builds — only device paths call this.
    pub fn client(&self) -> Result<xla::PjRtClient> {
        super::shared_client()
    }

    /// Does this store have artifacts for `model`?
    pub fn has_model(&self, model: &str) -> bool {
        self.root.join("weights").join(format!("{model}.lmz")).exists()
    }

    /// Load and validate the weights for a model.
    pub fn weights(&self, cfg: &LmConfig) -> Result<Weights> {
        let path = self.root.join("weights").join(format!("{}.lmz", cfg.name));
        Weights::load(&path, cfg)
    }

    /// Compile an HLO-text artifact.
    pub fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.root.join("hlo").join(file);
        if !path.exists() {
            anyhow::bail!("HLO artifact {} missing — run `make artifacts`", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client()?.compile(&comp).map_err(|e| anyhow::anyhow!("compiling {file}: {e}"))
    }

    /// Upload a model's parameters to device buffers, in canonical order.
    /// PJRT artifacts are lowered in f32, so quantized bundles are rejected
    /// here (the native engine is the int8 path).
    pub fn param_buffers(&self, cfg: &LmConfig, weights: &Weights) -> Result<Vec<xla::PjRtBuffer>> {
        let client = self.client()?;
        let mut bufs = Vec::with_capacity(weights.tensors.len());
        for t in &weights.tensors {
            let data = t.data.as_f32().ok_or_else(|| {
                anyhow::anyhow!(
                    "tensor '{}' is int8-quantized; PJRT executors need f32 weights (use the \
                     native engine for int8)",
                    t.name
                )
            })?;
            bufs.push(
                client
                    .buffer_from_host_buffer::<f32>(data, &t.shape, None)
                    .map_err(|e| anyhow::anyhow!("uploading {}: {e}", t.name))?,
            );
        }
        let _ = cfg;
        Ok(bufs)
    }

    /// Standard artifact file names.
    pub fn forward_file(cfg: &LmConfig) -> String {
        format!(
            "{}__forward_b{}_s{}.hlo.txt",
            cfg.name,
            config::FORWARD_BATCH,
            config::MAX_CONTEXT
        )
    }

    pub fn step_file(cfg: &LmConfig) -> String {
        format!("{}__step_b{}_s{}.hlo.txt", cfg.name, config::STEP_BATCH, config::MAX_CONTEXT)
    }

    pub fn generate_file(cfg: &LmConfig) -> String {
        format!(
            "{}__generate_b{}_p{}_n{}.hlo.txt",
            cfg.name,
            config::GEN_BATCH,
            config::GEN_PROMPT,
            config::GEN_TOKENS
        )
    }

    pub fn forward_pallas_file(cfg: &LmConfig) -> String {
        format!("{}__forward_pallas_b1_s{}.hlo.txt", cfg.name, config::MAX_CONTEXT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_reported() {
        let err = match ArtifactStore::open(Some("/nonexistent/path")) {
            Err(e) => e,
            Ok(_) => panic!("must fail"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn artifact_names_are_stable() {
        let cfg = config::by_name("medium").unwrap();
        assert_eq!(ArtifactStore::forward_file(cfg), "medium__forward_b8_s256.hlo.txt");
        assert_eq!(ArtifactStore::step_file(cfg), "medium__step_b32_s256.hlo.txt");
        assert_eq!(
            ArtifactStore::generate_file(cfg),
            "medium__generate_b16_p16_n240.hlo.txt"
        );
    }
}
