//! PJRT-backed executors.
//!
//! * [`PjrtForwardExecutor`] — wraps the lowered `forward_logits` HLO.
//!   Encoding gets all positions' logits in ONE device call per chunk
//!   batch; decoding replays the same executable on the growing prefix,
//!   which is bit-exact with the encode pass because position `t`'s logits
//!   depend only on tokens `<= t` (strict causal masking, position-local
//!   everything else — property tested in python and in
//!   `rust/tests/runtime_parity.rs`).
//! * [`PjrtStepExecutor`] — wraps the lowered `decode_step` HLO (KV cache
//!   threaded through each call). Symmetric cost for encode/decode.
//! * [`PjrtGenerator`] — wraps the lowered in-graph sampling loop, used by
//!   the dataset factory.

use crate::lm::config::{self, LmConfig};
use crate::lm::executor::{ExecutorKind, LmExecutor};
use crate::runtime::ArtifactStore;
use crate::tokenizer::vocab::PAD;
use crate::Result;

const VOCAB: usize = config::VOCAB;

/// Upload a typed host array as a device buffer. (Not the literal route:
/// `Literal::create_from_shape_and_untyped_data` + `buffer_from_host_literal`
/// mis-sizes some shapes in xla_extension 0.5.1.)
fn upload_i32(client: &xla::PjRtClient, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer::<i32>(data, dims, None)
        .map_err(|e| anyhow::anyhow!("uploading i32 {dims:?}: {e}"))
}

fn upload_f32(client: &xla::PjRtClient, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer::<f32>(data, dims, None)
        .map_err(|e| anyhow::anyhow!("uploading f32 {dims:?}: {e}"))
}

/// Forward-replay executor (see module docs).
pub struct PjrtForwardExecutor {
    cfg: &'static LmConfig,
    exe: xla::PjRtLoadedExecutable,
    params: Vec<xla::PjRtBuffer>,
    batch: usize,
    seq: usize,
    /// Tokens fed so far per lane (decode-side prefix replay).
    fed: Vec<Vec<u32>>,
}

impl PjrtForwardExecutor {
    pub fn from_store(store: &ArtifactStore, cfg: &'static LmConfig) -> Result<Self> {
        let weights = store.weights(cfg)?;
        let exe = store.compile(&ArtifactStore::forward_file(cfg))?;
        let params = store.param_buffers(cfg, &weights)?;
        Ok(PjrtForwardExecutor {
            cfg,
            exe,
            params,
            batch: config::FORWARD_BATCH,
            seq: config::MAX_CONTEXT,
            fed: vec![Vec::new(); config::FORWARD_BATCH],
        })
    }

    /// One raw forward pass. `tokens` is `[batch * seq]` row-major.
    /// Returns logits `[batch * seq * VOCAB]`.
    pub fn forward_raw(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        debug_assert_eq!(tokens.len(), self.batch * self.seq);
        let tok_buf = upload_i32(self.exe.client(), tokens, &[self.batch, self.seq])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&tok_buf);
        let result = self.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("forward: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching logits: {e}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow::anyhow!("untupling: {e}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("logits to_vec: {e}"))
    }

    /// Bulk encode path: feed each lane's full input (BOS + chunk bytes,
    /// `<= seq` long) and return logits for the first `n_positions` of every
    /// lane: `[lanes * n_positions * VOCAB]`. Exposed inherently (tests and
    /// tools call it on `&self`); [`LmExecutor::encode_logits`] delegates
    /// here, overriding the trait's stepping fallback with this one-call
    /// batched forward.
    pub fn encode_logits(&self, lanes: &[Vec<u32>], n_positions: usize) -> Result<Vec<f32>> {
        if lanes.len() > self.batch {
            anyhow::bail!("{} lanes > batch {}", lanes.len(), self.batch);
        }
        let mut tokens = vec![PAD as i32; self.batch * self.seq];
        for (l, lane) in lanes.iter().enumerate() {
            if lane.len() > self.seq {
                anyhow::bail!("lane {} length {} > seq {}", l, lane.len(), self.seq);
            }
            for (t, &tok) in lane.iter().enumerate() {
                tokens[l * self.seq + t] = tok as i32;
            }
        }
        let logits = self.forward_raw(&tokens)?;
        let mut out = Vec::with_capacity(lanes.len() * n_positions * VOCAB);
        for l in 0..lanes.len() {
            let base = l * self.seq * VOCAB;
            out.extend_from_slice(&logits[base..base + n_positions * VOCAB]);
        }
        Ok(out)
    }
}

impl LmExecutor for PjrtForwardExecutor {
    fn config(&self) -> &'static LmConfig {
        self.cfg
    }

    fn kind(&self) -> ExecutorKind {
        ExecutorKind::PjrtForward
    }

    fn lanes(&self) -> usize {
        self.batch
    }

    fn kernel_tier(&self) -> &'static str {
        "pjrt-hlo"
    }

    fn reset(&mut self) {
        for f in self.fed.iter_mut() {
            f.clear();
        }
    }

    /// Decode-side step: append one token per lane, replay the forward pass
    /// on the padded prefix, return the logits at the newest position.
    fn step(&mut self, toks: &[u32]) -> Result<Vec<f32>> {
        if toks.len() != self.batch {
            anyhow::bail!("step expects {} tokens, got {}", self.batch, toks.len());
        }
        let mut tokens = vec![PAD as i32; self.batch * self.seq];
        for (l, &tok) in toks.iter().enumerate() {
            self.fed[l].push(tok);
            if self.fed[l].len() > self.seq {
                anyhow::bail!("lane {l} overflow");
            }
            for (t, &ft) in self.fed[l].iter().enumerate() {
                tokens[l * self.seq + t] = ft as i32;
            }
        }
        let pos = self.fed[0].len() - 1;
        let logits = self.forward_raw(&tokens)?;
        let mut out = Vec::with_capacity(self.batch * VOCAB);
        for l in 0..self.batch {
            let base = (l * self.seq + pos) * VOCAB;
            out.extend_from_slice(&logits[base..base + VOCAB]);
        }
        Ok(out)
    }

    /// Encode-side bulk path: one device call for all positions (the whole
    /// point of this executor) instead of the trait's stepping fallback.
    fn encode_logits(&mut self, lanes: &[Vec<u32>], n_positions: usize) -> Result<Vec<f32>> {
        PjrtForwardExecutor::encode_logits(self, lanes, n_positions)
    }
}

/// KV-cache step executor (see module docs).
pub struct PjrtStepExecutor {
    cfg: &'static LmConfig,
    exe: xla::PjRtLoadedExecutable,
    params: Vec<xla::PjRtBuffer>,
    batch: usize,
    seq: usize,
    /// Current KV cache (device buffer), threaded through steps.
    kv: xla::PjRtBuffer,
    pos: usize,
}

impl PjrtStepExecutor {
    pub fn from_store(store: &ArtifactStore, cfg: &'static LmConfig) -> Result<Self> {
        let weights = store.weights(cfg)?;
        let exe = store.compile(&ArtifactStore::step_file(cfg))?;
        let params = store.param_buffers(cfg, &weights)?;
        let batch = config::STEP_BATCH;
        let seq = config::MAX_CONTEXT;
        let kv_elems = cfg.n_layers * 2 * batch * seq * cfg.d_model;
        let kv = store
            .client()?
            .buffer_from_host_buffer::<f32>(
                &vec![0.0f32; kv_elems],
                &[cfg.n_layers, 2, batch, seq, cfg.d_model],
                None,
            )
            .map_err(|e| anyhow::anyhow!("allocating kv: {e}"))?;
        Ok(PjrtStepExecutor { cfg, exe, params, batch, seq, kv, pos: 0 })
    }
}

impl LmExecutor for PjrtStepExecutor {
    fn config(&self) -> &'static LmConfig {
        self.cfg
    }

    fn kind(&self) -> ExecutorKind {
        ExecutorKind::PjrtStep
    }

    fn lanes(&self) -> usize {
        self.batch
    }

    fn kernel_tier(&self) -> &'static str {
        "pjrt-hlo"
    }

    fn reset(&mut self) {
        // Positions > pos are never read (causal mask), so the stale cache
        // contents are harmless; only the cursor resets.
        self.pos = 0;
    }

    fn step(&mut self, toks: &[u32]) -> Result<Vec<f32>> {
        if toks.len() != self.batch {
            anyhow::bail!("step expects {} tokens, got {}", self.batch, toks.len());
        }
        if self.pos >= self.seq {
            anyhow::bail!("step executor overflow at pos {}", self.pos);
        }
        let toks_i32: Vec<i32> = toks.iter().map(|&t| t as i32).collect();
        let client = self.exe.client();
        let tok_buf = upload_i32(client, &toks_i32, &[self.batch])?;
        let pos_buf = upload_i32(client, &[self.pos as i32], &[])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&self.kv);
        args.push(&tok_buf);
        args.push(&pos_buf);
        let result = self.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("step: {e}"))?;
        // The step artifact returns ONE flat f32 array: [logits | kv'] (the
        // published xla crate cannot fetch multi-element tuple buffers).
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching step outputs: {e}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow::anyhow!("untupling step: {e}"))?;
        let flat = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("step to_vec: {e}"))?;
        let n_logits = self.batch * VOCAB;
        let kv_elems = self.cfg.n_layers * 2 * self.batch * self.seq * self.cfg.d_model;
        if flat.len() != n_logits + kv_elems {
            anyhow::bail!("step output size {} != logits {} + kv {}", flat.len(), n_logits, kv_elems);
        }
        let logits = flat[..n_logits].to_vec();
        // Re-upload the new KV cache for the next step (host round-trip;
        // see EXPERIMENTS.md §Perf for the buffer-donation optimization).
        self.kv = client
            .buffer_from_host_buffer::<f32>(
                &flat[n_logits..],
                &[self.cfg.n_layers, 2, self.batch, self.seq, self.cfg.d_model],
                None,
            )
            .map_err(|e| anyhow::anyhow!("kv re-upload: {e}"))?;
        self.pos += 1;
        Ok(logits)
    }
}

/// In-graph sampling (dataset factory).
pub struct PjrtGenerator {
    cfg: &'static LmConfig,
    exe: xla::PjRtLoadedExecutable,
    params: Vec<xla::PjRtBuffer>,
    pub batch: usize,
    pub prompt_len: usize,
    pub n_tokens: usize,
}

impl PjrtGenerator {
    pub fn from_store(store: &ArtifactStore, cfg: &'static LmConfig) -> Result<Self> {
        let weights = store.weights(cfg)?;
        let exe = store.compile(&ArtifactStore::generate_file(cfg))?;
        let params = store.param_buffers(cfg, &weights)?;
        Ok(PjrtGenerator {
            cfg,
            exe,
            params,
            batch: config::GEN_BATCH,
            prompt_len: config::GEN_PROMPT,
            n_tokens: config::GEN_TOKENS,
        })
    }

    pub fn config(&self) -> &'static LmConfig {
        self.cfg
    }

    /// Sample continuations. `prompts` is `[batch][prompt_len]` tokens.
    /// Returns `[batch][n_tokens]`.
    pub fn generate(&self, prompts: &[Vec<u32>], seed: i32, temp: f32) -> Result<Vec<Vec<u32>>> {
        if prompts.len() != self.batch {
            anyhow::bail!("generator expects {} prompts, got {}", self.batch, prompts.len());
        }
        let mut toks = Vec::with_capacity(self.batch * self.prompt_len);
        for p in prompts {
            if p.len() != self.prompt_len {
                anyhow::bail!("prompt length {} != {}", p.len(), self.prompt_len);
            }
            toks.extend(p.iter().map(|&t| t as i32));
        }
        let client = self.exe.client();
        let prompt_buf = upload_i32(client, &toks, &[self.batch, self.prompt_len])?;
        let seed_buf = upload_i32(client, &[seed], &[])?;
        let temp_buf = upload_f32(client, &[temp], &[])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&prompt_buf);
        args.push(&seed_buf);
        args.push(&temp_buf);
        let result = self.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("generate: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching samples: {e}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow::anyhow!("untupling: {e}"))?;
        let flat = out.to_vec::<i32>().map_err(|e| anyhow::anyhow!("samples to_vec: {e}"))?;
        Ok(flat
            .chunks(self.n_tokens)
            .map(|row| row.iter().map(|&t| t as u32).collect())
            .collect())
    }
}
