//! PJRT runtime: load the AOT artifacts (HLO text + weights) and expose
//! them as [`crate::lm::LmExecutor`]s.
//!
//! Python never runs here — `artifacts/` is the only interface between the
//! build path and this request path:
//!
//! ```text
//! artifacts/weights/<model>.lmz                 trained parameters
//! artifacts/hlo/<model>__forward_b8_s256.hlo.txt
//! artifacts/hlo/<model>__step_b32_s256.hlo.txt
//! artifacts/hlo/<model>__generate_b16_p16_n240.hlo.txt
//! artifacts/manifest.txt
//! ```
//!
//! HLO *text* is the interchange format (jax>=0.5 protos carry 64-bit ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids).

pub mod artifacts;
pub mod executors;

pub use artifacts::ArtifactStore;
pub use executors::{PjrtForwardExecutor, PjrtGenerator, PjrtStepExecutor};

use crate::Result;

thread_local! {
    // PJRT handles are thread-affine (the xla crate wraps them in Rc), so
    // the client cache is per-thread. In practice exactly one worker thread
    // talks to PJRT.
    static CLIENT: std::cell::RefCell<Option<xla::PjRtClient>> =
        const { std::cell::RefCell::new(None) };
}

/// Per-thread PJRT CPU client (creating several is wasteful and noisy).
pub fn shared_client() -> Result<xla::PjRtClient> {
    CLIENT.with(|c| {
        let mut c = c.borrow_mut();
        if c.is_none() {
            *c = Some(
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?,
            );
        }
        Ok(c.clone().unwrap())
    })
}
