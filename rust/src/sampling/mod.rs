//! Dataset factory: produce the *LLM-generated* evaluation datasets by
//! temperature-sampling the trained LMs (paper §5.1.1 — every eval dataset
//! in the paper is itself LLM output; here the text is genuinely produced
//! by next-token sampling, which is exactly the property the paper's
//! compression result rests on).
//!
//! Two samplers:
//! * [`DatasetFactory`] — the lowered in-graph `generate` HLO (default;
//!   the whole sampling loop runs inside XLA, one call per block).
//! * [`NativeSampler`] — pure-rust Gumbel sampling over the native model
//!   (fallback; also used by tests so they need no artifacts).

use crate::lm::config::{self, LmConfig};
use crate::lm::native::{LaneState, NativeModel, Scratch};
use crate::lm::weights::Weights;
use crate::runtime::{ArtifactStore, PjrtGenerator};
use crate::textgen::Domain;
use crate::tokenizer::vocab::{Vocab, BOS};
use crate::util::Pcg64;
use crate::Result;

/// Build the BOS+domain-tag+primer prompt rows for a domain.
fn domain_prompts(domain: Domain, n: usize, prompt_len: usize) -> Vec<Vec<u32>> {
    let tag = Vocab.domain_tag(domain.index());
    // A few real corpus bytes prime the sampler into the domain's register.
    let primer = crate::textgen::generate(domain, 64, 999);
    (0..n)
        .map(|i| {
            let mut p = vec![BOS, tag];
            let off = (i * 7) % 32;
            p.extend(primer[off..off + prompt_len - 2].iter().map(|&b| b as u32));
            p
        })
        .collect()
}

/// Keep only byte tokens and newline-terminate blocks (decode safety).
fn tokens_to_bytes(rows: &[Vec<u32>]) -> Vec<u8> {
    let mut out = Vec::new();
    for row in rows {
        for &t in row {
            if t < 256 {
                out.push(t as u8);
            }
        }
        out.push(b'\n');
    }
    out
}

/// PJRT-backed dataset factory.
pub struct DatasetFactory {
    generator: PjrtGenerator,
}

impl DatasetFactory {
    /// Open for a model using the default artifact store location.
    pub fn open(model: &str) -> Result<DatasetFactory> {
        let store = ArtifactStore::open(None)?;
        Self::from_store(&store, model)
    }

    pub fn from_store(store: &ArtifactStore, model: &str) -> Result<DatasetFactory> {
        let cfg = config::by_name(model)?;
        Ok(DatasetFactory { generator: PjrtGenerator::from_store(store, cfg)? })
    }

    pub fn config(&self) -> &'static LmConfig {
        self.generator.config()
    }

    /// Generate at least `min_bytes` of domain-conditioned samples.
    pub fn generate_dataset(
        &self,
        domain: Domain,
        min_bytes: usize,
        temp: f64,
        seed: u64,
    ) -> Result<Vec<u8>> {
        let b = self.generator.batch;
        let p = self.generator.prompt_len;
        let mut out = Vec::with_capacity(min_bytes + 4096);
        let mut call = 0u32;
        while out.len() < min_bytes {
            let prompts = domain_prompts(domain, b, p);
            let call_seed = (seed as i32)
                .wrapping_mul(2654435761u32 as i32)
                .wrapping_add(call as i32)
                .wrapping_add(domain.index() as i32 * 7919);
            let rows = self.generator.generate(&prompts, call_seed, temp as f32)?;
            out.extend(tokens_to_bytes(&rows));
            call += 1;
        }
        out.truncate(min_bytes);
        Ok(out)
    }
}

/// Native (no-PJRT) sampler over [`NativeModel`].
///
/// Sampling is batched over prompts: [`NativeSampler::sample_batch`] runs
/// all lanes through [`NativeModel::advance_batch`] with ONE shared
/// [`Scratch`] arena (the single-lane `advance` wrapper used to allocate a
/// one-lane scratch per decoded token). Per-lane bytes are bit-identical
/// to single-lane sampling for a fixed seed: logits are bit-exact across
/// lane batchings and each lane draws from its own seeded RNG.
pub struct NativeSampler {
    model: NativeModel,
}

/// Lanes used by [`NativeSampler::generate_dataset`] (blocks sampled in
/// parallel; pure execution knob — the output bytes don't depend on it).
const GEN_LANES: usize = 4;

impl NativeSampler {
    pub fn new(cfg: &'static LmConfig, weights: Weights) -> Self {
        NativeSampler { model: NativeModel::new(cfg, weights) }
    }

    /// Sample `n_tokens` bytes continuing `prompt` (Gumbel-max over
    /// temperature-scaled byte logits). One-lane wrapper over
    /// [`Self::sample_batch`].
    pub fn sample(&self, prompt: &[u32], n_tokens: usize, temp: f64, seed: u64) -> Result<Vec<u8>> {
        let mut out = self.sample_batch(&[prompt.to_vec()], n_tokens, temp, &[seed])?;
        Ok(out.pop().expect("one lane in, one lane out"))
    }

    /// Sample one continuation per prompt, all lanes stepped together
    /// through the batched engine. Prompts may be **ragged** (any mix of
    /// lengths ≥ 1): each lane carries its own position offset, so lane
    /// `l`'s context is exactly `prompts[l]` — never padding. Lane `l`
    /// draws from its own RNG seeded with `seeds[l]`, so each lane's bytes
    /// are identical to `sample(prompts[l], .., seeds[l])` run alone.
    ///
    /// Mechanics: lanes are sorted by descending prompt length and started
    /// right-aligned (lane `l` joins the batch at step `max_len - len_l`,
    /// beginning at KV position 0), so the active set during prompt replay
    /// is a growing prefix of the sorted order and every lane finishes its
    /// prompt on the same step. During sampling, lanes that exhaust
    /// `MAX_CONTEXT` retire longest-first — a shrinking suffix — so every
    /// engine call still operates on one contiguous lane span. Per-lane
    /// logits are bit-exact for any batching, which is what makes the
    /// whole schedule a pure execution detail.
    pub fn sample_batch(
        &self,
        prompts: &[Vec<u32>],
        n_tokens: usize,
        temp: f64,
        seeds: &[u64],
    ) -> Result<Vec<Vec<u8>>> {
        let n = prompts.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        if seeds.len() != n {
            anyhow::bail!("sample_batch: {} prompts but {} seeds", n, seeds.len());
        }
        if prompts.iter().any(|p| p.is_empty()) {
            anyhow::bail!("sample_batch: prompts must be non-empty");
        }
        let max_len = prompts.iter().map(|p| p.len()).max().expect("n > 0");
        // Sorted lane order, longest prompt first (stable: equal lengths
        // keep their original order, so the schedule is deterministic).
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(prompts[i].len()));

        let cfg = self.model.cfg;
        let mut rngs: Vec<Pcg64> = order.iter().map(|&i| Pcg64::new(seeds[i], 31)).collect();
        let mut lanes: Vec<LaneState> =
            (0..n).map(|_| LaneState::new(cfg, config::MAX_CONTEXT)).collect();
        let mut scratch = Scratch::new(cfg, n);
        let mut logits = vec![0.0f32; n * config::VOCAB];
        let mut toks = vec![0u32; n];
        // Prompt replay, right-aligned: at step t the active lanes are the
        // sorted prefix whose prompts have started (len >= max_len - t).
        // The buffer ends up holding every lane's logits at its last
        // prompt token.
        for t in 0..max_len {
            let active =
                order.iter().take_while(|&&i| prompts[i].len() >= max_len - t).count();
            for (slot, &i) in order[..active].iter().enumerate() {
                toks[slot] = prompts[i][t - (max_len - prompts[i].len())];
            }
            self.model.advance_batch(
                &mut lanes[..active],
                &toks[..active],
                &mut scratch,
                &mut logits[..active * config::VOCAB],
                config::VOCAB,
            )?;
        }
        let mut outs: Vec<Vec<u8>> = (0..n).map(|_| Vec::with_capacity(n_tokens)).collect();
        // Sampling: lane (sorted slot) k sits at position prompts[order[k]]
        // .len() + produced; the longest lanes hit MAX_CONTEXT first, so
        // retired lanes accumulate at the front of the sorted order.
        let mut first_live = 0usize;
        for _ in 0..n_tokens {
            while first_live < n && lanes[first_live].pos() >= config::MAX_CONTEXT {
                first_live += 1;
            }
            if first_live == n {
                break;
            }
            let inv_t = 1.0 / temp.max(1e-4) as f32;
            for k in first_live..n {
                let lane_logits = &logits[k * config::VOCAB..(k + 1) * config::VOCAB];
                let rng = &mut rngs[k];
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (s, &lo) in lane_logits.iter().take(256).enumerate() {
                    let u = rng.gen_f64().max(1e-12);
                    let gumbel = -(-(u.ln())).ln();
                    let v = lo * inv_t + gumbel as f32;
                    if v > best_v {
                        best_v = v;
                        best = s;
                    }
                }
                outs[order[k]].push(best as u8);
                toks[k] = best as u32;
            }
            self.model.advance_batch(
                &mut lanes[first_live..],
                &toks[first_live..],
                &mut scratch,
                &mut logits[first_live * config::VOCAB..],
                config::VOCAB,
            )?;
        }
        Ok(outs)
    }

    /// Dataset-shaped output: repeated blocks until `min_bytes`, sampled
    /// [`GEN_LANES`] blocks at a time. Identical bytes to the serial
    /// one-block-at-a-time path for a fixed seed: every block uses the
    /// same prompt row and the same per-block seed schedule, lanes are
    /// bit-exact, and the final truncate discards any overshoot.
    pub fn generate_dataset(
        &self,
        domain: Domain,
        min_bytes: usize,
        temp: f64,
        seed: u64,
    ) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(min_bytes + 1024);
        let mut block = 0u64;
        let prompt = domain_prompts(domain, 1, config::GEN_PROMPT).pop().expect("one prompt");
        while out.len() < min_bytes {
            // Don't fan out further than the remaining byte budget needs
            // (a block yields <= GEN_TOKENS + 1 bytes); per-block seeds are
            // indexed by `block`, so the lane count never changes the bytes.
            let remaining = min_bytes - out.len();
            let lanes = remaining.div_ceil(config::GEN_TOKENS + 1).clamp(1, GEN_LANES);
            let prompts: Vec<Vec<u32>> = (0..lanes).map(|_| prompt.clone()).collect();
            let seeds: Vec<u64> = (0..lanes as u64)
                .map(|i| seed.wrapping_mul(0x9E37_79B9).wrapping_add(block + i))
                .collect();
            for bytes in self.sample_batch(&prompts, config::GEN_TOKENS, temp, &seeds)? {
                out.extend(bytes);
                out.push(b'\n');
            }
            block += lanes as u64;
        }
        out.truncate(min_bytes);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;

    #[test]
    fn prompts_are_domain_tagged() {
        let p = domain_prompts(Domain::Math, 4, 16);
        assert_eq!(p.len(), 4);
        for row in &p {
            assert_eq!(row.len(), 16);
            assert_eq!(row[0], BOS);
            assert_eq!(row[1], Vocab.domain_tag(Domain::Math.index()));
        }
        // Different rows use different primer offsets.
        assert_ne!(p[0], p[1]);
    }

    #[test]
    fn tokens_to_bytes_filters_specials() {
        let rows = vec![vec![72u32, 105, 300, 257, 33]];
        assert_eq!(tokens_to_bytes(&rows), b"Hi!\n");
    }

    #[test]
    fn native_sampler_is_deterministic() {
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 11));
        let a = s.sample(&[BOS], 40, 0.8, 5).unwrap();
        let b = s.sample(&[BOS], 40, 0.8, 5).unwrap();
        let c = s.sample(&[BOS], 40, 0.8, 6).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 40);
    }

    #[test]
    fn low_temperature_reduces_diversity() {
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 12));
        let hot = s.sample(&[BOS], 200, 1.5, 1).unwrap();
        let cold = s.sample(&[BOS], 200, 0.05, 1).unwrap();
        let distinct = |v: &[u8]| v.iter().collect::<std::collections::HashSet<_>>().len();
        assert!(distinct(&cold) <= distinct(&hot), "cold {} hot {}", distinct(&cold), distinct(&hot));
    }

    #[test]
    fn native_dataset_shape() {
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 13));
        let d = s.generate_dataset(Domain::Wiki, 600, 0.9, 3).unwrap();
        assert_eq!(d.len(), 600);
    }

    #[test]
    fn batched_sampling_matches_single_lane_bit_for_bit() {
        // The batched sampler must reproduce each lane's single-lane bytes
        // exactly: batching is a pure execution knob, like engine threads.
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 14));
        let p = domain_prompts(Domain::Math, 1, 12).pop().unwrap();
        let seeds = [7u64, 8, 9];
        let prompts = vec![p.clone(), p.clone(), p.clone()];
        let batch = s.sample_batch(&prompts, 25, 0.9, &seeds).unwrap();
        for (l, &seed) in seeds.iter().enumerate() {
            assert_eq!(batch[l], s.sample(&p, 25, 0.9, seed).unwrap(), "lane {l} seed {seed}");
        }
        assert!(s.sample_batch(&prompts, 5, 0.9, &[1, 2]).is_err(), "seed count checked");
        assert!(s.sample_batch(&[vec![]], 5, 0.9, &[1]).is_err(), "empty prompt rejected");
    }

    #[test]
    fn ragged_batch_matches_sequential_sampling_bit_for_bit() {
        // The ROADMAP open item: ragged prompts batch via per-lane
        // position offsets, and every lane's bytes equal the per-prompt
        // sequential path exactly (each lane's context is its own prompt,
        // never padding).
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 16));
        let long = domain_prompts(Domain::Wiki, 1, 14).pop().unwrap();
        let prompts = vec![
            long[..5].to_vec(),
            long.clone(),
            long[..9].to_vec(),
            long[..9].iter().rev().copied().collect::<Vec<u32>>(),
            vec![BOS],
        ];
        let seeds = [3u64, 1, 4, 1, 5];
        let batch = s.sample_batch(&prompts, 30, 0.9, &seeds).unwrap();
        for (l, (p, &seed)) in prompts.iter().zip(&seeds).enumerate() {
            let want = s.sample(p, 30, 0.9, seed).unwrap();
            assert_eq!(batch[l], want, "lane {l} (prompt len {})", p.len());
            assert_eq!(batch[l].len(), 30);
        }
    }

    #[test]
    fn ragged_lanes_retire_at_context_end_like_sequential() {
        // A lane whose prompt nearly fills MAX_CONTEXT stops early while
        // shorter lanes keep producing — byte-identical to running each
        // prompt alone.
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 17));
        let near_full: Vec<u32> =
            (0..config::MAX_CONTEXT - 4).map(|i| (i % 256) as u32).collect();
        let prompts = vec![near_full.clone(), near_full[..20].to_vec()];
        let seeds = [8u64, 9];
        let batch = s.sample_batch(&prompts, 10, 0.9, &seeds).unwrap();
        assert_eq!(batch[0].len(), 4, "long lane retires at MAX_CONTEXT");
        assert_eq!(batch[1].len(), 10);
        for (l, (p, &seed)) in prompts.iter().zip(&seeds).enumerate() {
            assert_eq!(batch[l], s.sample(p, 10, 0.9, seed).unwrap(), "lane {l}");
        }
    }

    #[test]
    fn batched_dataset_matches_serial_block_schedule() {
        // generate_dataset samples GEN_LANES blocks per engine pass; the
        // bytes must equal the serial one-block-at-a-time construction.
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 15));
        let (min_bytes, temp, seed) = (500usize, 0.9, 5u64);
        let got = s.generate_dataset(Domain::Wiki, min_bytes, temp, seed).unwrap();
        let mut want = Vec::new();
        let mut block = 0u64;
        while want.len() < min_bytes {
            let prompt =
                domain_prompts(Domain::Wiki, 1, config::GEN_PROMPT).pop().unwrap();
            let bytes = s
                .sample(
                    &prompt,
                    crate::lm::config::GEN_TOKENS,
                    temp,
                    seed.wrapping_mul(0x9E37_79B9).wrapping_add(block),
                )
                .unwrap();
            want.extend(bytes);
            want.push(b'\n');
            block += 1;
        }
        want.truncate(min_bytes);
        assert_eq!(got, want);
    }
}
