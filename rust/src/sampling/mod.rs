//! Dataset factory: produce the *LLM-generated* evaluation datasets by
//! temperature-sampling the trained LMs (paper §5.1.1 — every eval dataset
//! in the paper is itself LLM output; here the text is genuinely produced
//! by next-token sampling, which is exactly the property the paper's
//! compression result rests on).
//!
//! Two samplers:
//! * [`DatasetFactory`] — the lowered in-graph `generate` HLO (default;
//!   the whole sampling loop runs inside XLA, one call per block).
//! * [`NativeSampler`] — pure-rust Gumbel sampling over the native model
//!   (fallback; also used by tests so they need no artifacts).

use crate::lm::config::{self, LmConfig};
use crate::lm::native::{LaneState, NativeModel};
use crate::lm::weights::Weights;
use crate::runtime::{ArtifactStore, PjrtGenerator};
use crate::textgen::Domain;
use crate::tokenizer::vocab::{Vocab, BOS};
use crate::util::Pcg64;
use crate::Result;

/// Build the BOS+domain-tag+primer prompt rows for a domain.
fn domain_prompts(domain: Domain, n: usize, prompt_len: usize) -> Vec<Vec<u32>> {
    let tag = Vocab.domain_tag(domain.index());
    // A few real corpus bytes prime the sampler into the domain's register.
    let primer = crate::textgen::generate(domain, 64, 999);
    (0..n)
        .map(|i| {
            let mut p = vec![BOS, tag];
            let off = (i * 7) % 32;
            p.extend(primer[off..off + prompt_len - 2].iter().map(|&b| b as u32));
            p
        })
        .collect()
}

/// Keep only byte tokens and newline-terminate blocks (decode safety).
fn tokens_to_bytes(rows: &[Vec<u32>]) -> Vec<u8> {
    let mut out = Vec::new();
    for row in rows {
        for &t in row {
            if t < 256 {
                out.push(t as u8);
            }
        }
        out.push(b'\n');
    }
    out
}

/// PJRT-backed dataset factory.
pub struct DatasetFactory {
    generator: PjrtGenerator,
}

impl DatasetFactory {
    /// Open for a model using the default artifact store location.
    pub fn open(model: &str) -> Result<DatasetFactory> {
        let store = ArtifactStore::open(None)?;
        Self::from_store(&store, model)
    }

    pub fn from_store(store: &ArtifactStore, model: &str) -> Result<DatasetFactory> {
        let cfg = config::by_name(model)?;
        Ok(DatasetFactory { generator: PjrtGenerator::from_store(store, cfg)? })
    }

    pub fn config(&self) -> &'static LmConfig {
        self.generator.config()
    }

    /// Generate at least `min_bytes` of domain-conditioned samples.
    pub fn generate_dataset(
        &self,
        domain: Domain,
        min_bytes: usize,
        temp: f64,
        seed: u64,
    ) -> Result<Vec<u8>> {
        let b = self.generator.batch;
        let p = self.generator.prompt_len;
        let mut out = Vec::with_capacity(min_bytes + 4096);
        let mut call = 0u32;
        while out.len() < min_bytes {
            let prompts = domain_prompts(domain, b, p);
            let call_seed = (seed as i32)
                .wrapping_mul(2654435761u32 as i32)
                .wrapping_add(call as i32)
                .wrapping_add(domain.index() as i32 * 7919);
            let rows = self.generator.generate(&prompts, call_seed, temp as f32)?;
            out.extend(tokens_to_bytes(&rows));
            call += 1;
        }
        out.truncate(min_bytes);
        Ok(out)
    }
}

/// Native (no-PJRT) sampler over [`NativeModel`].
pub struct NativeSampler {
    model: NativeModel,
}

impl NativeSampler {
    pub fn new(cfg: &'static LmConfig, weights: Weights) -> Self {
        NativeSampler { model: NativeModel::new(cfg, weights) }
    }

    /// Sample `n_tokens` bytes continuing `prompt` (Gumbel-max over
    /// temperature-scaled byte logits).
    pub fn sample(&self, prompt: &[u32], n_tokens: usize, temp: f64, seed: u64) -> Result<Vec<u8>> {
        let mut rng = Pcg64::new(seed, 31);
        let mut lane = LaneState::new(self.model.cfg, config::MAX_CONTEXT);
        let mut out = Vec::with_capacity(n_tokens);
        let mut logits = vec![0.0f32; config::VOCAB];
        for (i, &t) in prompt.iter().enumerate() {
            let l = self.model.advance(&mut lane, t)?;
            if i == prompt.len() - 1 {
                logits = l;
            }
        }
        for _ in 0..n_tokens {
            if lane.pos() >= config::MAX_CONTEXT {
                break;
            }
            let inv_t = 1.0 / temp.max(1e-4) as f32;
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (s, &lo) in logits.iter().take(256).enumerate() {
                let u = rng.gen_f64().max(1e-12);
                let gumbel = -(-(u.ln())).ln();
                let v = lo * inv_t + gumbel as f32;
                if v > best_v {
                    best_v = v;
                    best = s;
                }
            }
            out.push(best as u8);
            logits = self.model.advance(&mut lane, best as u32)?;
        }
        Ok(out)
    }

    /// Dataset-shaped output: repeated blocks until `min_bytes`.
    pub fn generate_dataset(
        &self,
        domain: Domain,
        min_bytes: usize,
        temp: f64,
        seed: u64,
    ) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(min_bytes + 1024);
        let mut block = 0u64;
        while out.len() < min_bytes {
            let prompts = domain_prompts(domain, 1, config::GEN_PROMPT);
            let bytes = self.sample(
                &prompts[0],
                config::GEN_TOKENS,
                temp,
                seed.wrapping_mul(0x9E37_79B9).wrapping_add(block),
            )?;
            out.extend(bytes);
            out.push(b'\n');
            block += 1;
        }
        out.truncate(min_bytes);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::config::by_name;

    #[test]
    fn prompts_are_domain_tagged() {
        let p = domain_prompts(Domain::Math, 4, 16);
        assert_eq!(p.len(), 4);
        for row in &p {
            assert_eq!(row.len(), 16);
            assert_eq!(row[0], BOS);
            assert_eq!(row[1], Vocab.domain_tag(Domain::Math.index()));
        }
        // Different rows use different primer offsets.
        assert_ne!(p[0], p[1]);
    }

    #[test]
    fn tokens_to_bytes_filters_specials() {
        let rows = vec![vec![72u32, 105, 300, 257, 33]];
        assert_eq!(tokens_to_bytes(&rows), b"Hi!\n");
    }

    #[test]
    fn native_sampler_is_deterministic() {
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 11));
        let a = s.sample(&[BOS], 40, 0.8, 5).unwrap();
        let b = s.sample(&[BOS], 40, 0.8, 5).unwrap();
        let c = s.sample(&[BOS], 40, 0.8, 6).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 40);
    }

    #[test]
    fn low_temperature_reduces_diversity() {
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 12));
        let hot = s.sample(&[BOS], 200, 1.5, 1).unwrap();
        let cold = s.sample(&[BOS], 200, 0.05, 1).unwrap();
        let distinct = |v: &[u8]| v.iter().collect::<std::collections::HashSet<_>>().len();
        assert!(distinct(&cold) <= distinct(&hot), "cold {} hot {}", distinct(&cold), distinct(&hot));
    }

    #[test]
    fn native_dataset_shape() {
        let cfg = by_name("nano").unwrap();
        let s = NativeSampler::new(cfg, Weights::random(cfg, 13));
        let d = s.generate_dataset(Domain::Wiki, 600, 0.9, 3).unwrap();
        assert_eq!(d.len(), 600);
    }
}
