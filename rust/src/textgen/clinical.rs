//! Synthetic clinical discharge notes (the paper's Clinical dataset is
//! Asclepius-style GPT-3.5 notes in a Note–Question–Answer layout).

use super::lexicon::PERSON_NAMES;
use crate::util::Pcg64;

const CONDITIONS: &[&str] = &[
    "community-acquired pneumonia", "acute cholecystitis", "atrial fibrillation",
    "type 2 diabetes mellitus", "chronic obstructive pulmonary disease", "iron deficiency anemia",
    "acute appendicitis", "congestive heart failure", "urinary tract infection",
    "deep vein thrombosis",
];

const MEDICATIONS: &[&str] = &[
    "amoxicillin", "metformin", "lisinopril", "atorvastatin", "warfarin", "furosemide",
    "omeprazole", "prednisone", "azithromycin", "apixaban",
];

const PROCEDURES: &[&str] = &[
    "laparoscopic cholecystectomy", "chest radiography", "echocardiography", "colonoscopy",
    "CT of the abdomen", "pulmonary function testing", "cardiac catheterization",
];

const FINDINGS: &[&str] = &[
    "stable vital signs", "mild leukocytosis", "elevated inflammatory markers",
    "improved oxygen saturation", "resolution of symptoms", "no acute distress",
    "normal sinus rhythm", "adequate pain control",
];

/// One Note–Question–Answer clinical document.
pub fn document(rng: &mut Pcg64) -> String {
    let age = 22 + rng.gen_range(70);
    let sex = if rng.gen_bool(0.5) { "male" } else { "female" };
    let cond = rng.choose(CONDITIONS);
    let med = rng.choose(MEDICATIONS);
    let proc_ = rng.choose(PROCEDURES);
    let finding = rng.choose(FINDINGS);
    let days = 2 + rng.gen_range(12);
    let dr = rng.choose(PERSON_NAMES);
    let mut doc = format!(
        "Clinical Note: The patient is a {age}-year-old {sex} admitted with {cond}. \
         On admission the patient underwent {proc_}, which demonstrated {finding}. \
         Treatment with {med} was initiated under the care of Dr. {dr}. "
    );
    doc.push_str(&format!(
        "The hospital course was uncomplicated and the patient was discharged after {days} days \
         with {finding2}.\n",
        finding2 = rng.choose(FINDINGS),
    ));
    doc.push_str(&format!(
        "Question: What was the indication for {med} in this patient?\n\
         Answer: The patient was treated with {med} for {cond}, with follow-up showing {finding3}.",
        finding3 = rng.choose(FINDINGS),
    ));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn note_question_answer_layout() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..20 {
            let d = document(&mut rng);
            assert!(d.starts_with("Clinical Note:"));
            assert!(d.contains("\nQuestion:"));
            assert!(d.contains("\nAnswer:"));
        }
    }

    #[test]
    fn mentions_condition_and_medication() {
        let mut rng = Pcg64::seeded(2);
        let d = document(&mut rng);
        assert!(CONDITIONS.iter().any(|c| d.contains(c)));
        assert!(MEDICATIONS.iter().any(|m| d.contains(m)));
    }
}
