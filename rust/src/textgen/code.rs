//! Code-generation dataset: programming problems with solutions in a
//! Python-like and a C-like surface syntax (the paper's Code dataset was
//! produced by Mixtral across Python/JS/TS/C++/C).

use crate::util::Pcg64;

const FUNC_VERBS: &[&str] = &[
    "compute", "find", "count", "sum", "filter", "merge", "sort", "reverse", "parse", "encode",
    "validate", "normalize", "transform", "scan",
];

const FUNC_OBJECTS: &[&str] = &[
    "items", "values", "tokens", "records", "nodes", "pairs", "digits", "entries", "scores",
    "elements", "buckets", "segments",
];

const VAR_NAMES: &[&str] = &["acc", "result", "total", "buf", "out", "tmp", "count", "idx"];

fn func_name(rng: &mut Pcg64) -> String {
    format!("{}_{}", rng.choose(FUNC_VERBS), rng.choose(FUNC_OBJECTS))
}

fn python_function(rng: &mut Pcg64) -> String {
    let name = func_name(rng);
    let arg = rng.choose(FUNC_OBJECTS);
    let var = rng.choose(VAR_NAMES);
    let op = rng.choose(&["+", "*", "-"]);
    let cond = rng.choose(&["% 2 == 0", "> 0", "!= 0", "< limit"]);
    let mut f = format!("def {name}({arg}, limit={}):\n", 1 + rng.gen_range(100));
    f.push_str(&format!("    \"\"\"{} the {} in the input list.\"\"\"\n",
        super::lexicon::capitalize(rng.choose(FUNC_VERBS)), arg));
    f.push_str(&format!("    {var} = {}\n", rng.gen_index(2)));
    f.push_str(&format!("    for x in {arg}:\n"));
    f.push_str(&format!("        if x {cond}:\n"));
    f.push_str(&format!("            {var} = {var} {op} x\n"));
    f.push_str(&format!("    return {var}\n"));
    f
}

fn c_function(rng: &mut Pcg64) -> String {
    let name = func_name(rng);
    let var = rng.choose(VAR_NAMES);
    let op = rng.choose(&["+", "*", "^"]);
    let cond = rng.choose(&["% 2 == 0", "> threshold", "!= 0"]);
    let mut f = format!("int {name}(const int *data, int n, int threshold) {{\n");
    f.push_str(&format!("    int {var} = {};\n", rng.gen_index(2)));
    f.push_str("    for (int i = 0; i < n; i++) {\n");
    f.push_str(&format!("        if (data[i] {cond}) {{\n"));
    f.push_str(&format!("            {var} = {var} {op} data[i];\n"));
    f.push_str("        }\n    }\n");
    f.push_str(&format!("    return {var};\n}}\n"));
    f
}

/// One problem + solution document.
pub fn document(rng: &mut Pcg64) -> String {
    let verb = rng.choose(FUNC_VERBS);
    let obj = rng.choose(FUNC_OBJECTS);
    let lang_is_python = rng.gen_bool(0.6);
    let mut doc = format!(
        "Problem: Write a function to {verb} the {obj} of a list, \
         handling the empty case and negative inputs.\n\nSolution ({lang}):\n```\n",
        lang = if lang_is_python { "python" } else { "c" },
    );
    let n_funcs = 1 + rng.gen_index(2);
    for _ in 0..n_funcs {
        doc.push_str(&if lang_is_python { python_function(rng) } else { c_function(rng) });
        doc.push('\n');
    }
    doc.push_str("```\n");
    if rng.gen_bool(0.6) {
        doc.push_str(&format!(
            "Explanation: the function iterates once over the input, so it runs in O(n) \
             time and O(1) space. {}\n",
            super::lexicon::sentence(rng, FUNC_OBJECTS, &["iterative", "linear", "constant"]),
        ));
    }
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documents_contain_code_fences() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..20 {
            let d = document(&mut rng);
            assert!(d.contains("```"));
            assert!(d.contains("Problem:"));
        }
    }

    #[test]
    fn python_function_shape() {
        let mut rng = Pcg64::seeded(2);
        let f = python_function(&mut rng);
        assert!(f.starts_with("def "));
        assert!(f.contains("return"));
        assert!(f.contains("for x in"));
    }

    #[test]
    fn c_function_shape() {
        let mut rng = Pcg64::seeded(3);
        let f = c_function(&mut rng);
        assert!(f.starts_with("int "));
        assert!(f.contains("for (int i"));
        assert!(f.trim_end().ends_with('}'));
    }
}
