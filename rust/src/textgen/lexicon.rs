//! Shared word banks and sentence-building helpers for the corpus
//! generators. Kept in one place so domains share a base vocabulary (as
//! natural language domains do) while layering their own jargon on top.

use crate::util::Pcg64;

pub const DETERMINERS: &[&str] = &["the", "a", "this", "that", "each", "its"];

pub const COMMON_NOUNS: &[&str] = &[
    "system", "method", "result", "process", "structure", "model", "analysis", "approach",
    "region", "period", "development", "history", "population", "theory", "value", "effect",
    "study", "group", "form", "part", "work", "field", "role", "change", "state", "case",
];

pub const COMMON_VERBS: &[&str] = &[
    "is", "was", "remains", "became", "includes", "provides", "shows", "describes", "represents",
    "contains", "supports", "follows", "requires", "produces", "defines", "forms",
];

pub const COMMON_ADJS: &[&str] = &[
    "important", "significant", "notable", "common", "early", "modern", "large", "small",
    "central", "major", "primary", "complex", "simple", "general", "specific", "recent",
    "traditional", "distinct", "widespread", "fundamental",
];

pub const PLACE_NAMES: &[&str] = &[
    "Avaria", "Brenthal", "Corvann", "Dresmore", "Elvast", "Fenwick", "Galdoria", "Harnmouth",
    "Iskarel", "Jorvik", "Kestwell", "Lorvane", "Mersenne", "Northgate", "Ostmark", "Pellwater",
];

pub const PERSON_NAMES: &[&str] = &[
    "Aldren", "Bessemer", "Caldwell", "Derring", "Ellsworth", "Farrow", "Greaves", "Holloway",
    "Ingram", "Jessop", "Kirkwood", "Lambert", "Merriweather", "Norwood", "Ormsby", "Pemberton",
];

pub const FIRST_NAMES: &[&str] = &[
    "Alice", "Benjamin", "Clara", "Daniel", "Eleanor", "Frederick", "Grace", "Henry", "Isabel",
    "James", "Katherine", "Louis", "Margaret", "Nathaniel", "Olivia", "Peter",
];

pub const TRANSITIONS: &[&str] = &[
    "However,", "Moreover,", "In addition,", "As a result,", "Consequently,", "In contrast,",
    "Furthermore,", "Nevertheless,", "In particular,", "For example,",
];

/// Capitalize the first ASCII letter of a string.
pub fn capitalize(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_ascii_uppercase().to_string() + c.as_str(),
        None => String::new(),
    }
}

/// A generic subject-verb-object sentence from mixed banks.
pub fn sentence(rng: &mut Pcg64, extra_nouns: &[&str], extra_adjs: &[&str]) -> String {
    let noun = |rng: &mut Pcg64| -> &str {
        if !extra_nouns.is_empty() && rng.gen_bool(0.55) {
            rng.choose(extra_nouns)
        } else {
            rng.choose(COMMON_NOUNS)
        }
    };
    let adj = |rng: &mut Pcg64| -> &str {
        if !extra_adjs.is_empty() && rng.gen_bool(0.5) {
            rng.choose(extra_adjs)
        } else {
            rng.choose(COMMON_ADJS)
        }
    };
    let mut s = String::new();
    if rng.gen_bool(0.18) {
        s.push_str(rng.choose(TRANSITIONS));
        s.push(' ');
    }
    s.push_str(&capitalize(rng.choose(DETERMINERS)));
    s.push(' ');
    if rng.gen_bool(0.6) {
        s.push_str(adj(rng));
        s.push(' ');
    }
    s.push_str(noun(rng));
    s.push(' ');
    s.push_str(rng.choose(COMMON_VERBS));
    s.push(' ');
    s.push_str(rng.choose(DETERMINERS));
    s.push(' ');
    if rng.gen_bool(0.45) {
        s.push_str(adj(rng));
        s.push(' ');
    }
    s.push_str(noun(rng));
    match rng.gen_index(10) {
        0..=6 => s.push('.'),
        7 | 8 => {
            s.push_str(" of ");
            s.push_str(rng.choose(DETERMINERS));
            s.push(' ');
            s.push_str(noun(rng));
            s.push('.');
        }
        _ => {
            s.push_str(", which ");
            s.push_str(rng.choose(COMMON_VERBS));
            s.push(' ');
            s.push_str(rng.choose(DETERMINERS));
            s.push(' ');
            s.push_str(noun(rng));
            s.push('.');
        }
    }
    s
}

/// A paragraph of `n` sentences.
pub fn paragraph(rng: &mut Pcg64, n: usize, extra_nouns: &[&str], extra_adjs: &[&str]) -> String {
    let mut p = String::new();
    for i in 0..n {
        if i > 0 {
            p.push(' ');
        }
        p.push_str(&sentence(rng, extra_nouns, extra_adjs));
    }
    p
}

/// A random 4-digit year in [1650, 2024].
pub fn year(rng: &mut Pcg64) -> u32 {
    1650 + rng.gen_range(375) as u32
}

/// A small integer rendered in decimal.
pub fn small_int(rng: &mut Pcg64, max: u64) -> u64 {
    1 + rng.gen_range(max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentences_end_with_period() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..200 {
            let s = sentence(&mut rng, &["token"], &["lossless"]);
            assert!(s.ends_with('.'), "{s}");
            assert!(s.chars().next().unwrap().is_ascii_uppercase(), "{s}");
        }
    }

    #[test]
    fn paragraph_has_n_periods_at_least() {
        let mut rng = Pcg64::seeded(2);
        let p = paragraph(&mut rng, 5, &[], &[]);
        assert!(p.matches('.').count() >= 5);
    }

    #[test]
    fn capitalize_handles_edge_cases() {
        assert_eq!(capitalize(""), "");
        assert_eq!(capitalize("abc"), "Abc");
        assert_eq!(capitalize("Abc"), "Abc");
    }

    #[test]
    fn year_in_range() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..100 {
            let y = year(&mut rng);
            assert!((1650..=2024).contains(&y));
        }
    }
}
