//! Grade-school math word problems with worked solutions (the paper's
//! Math dataset is Orca-Math-style GPT-4-generated word problems).
//! Problems are arithmetically *consistent*: the stated answer is computed,
//! so the text carries real structure for a model to learn.

use super::lexicon::FIRST_NAMES;
use crate::util::Pcg64;

const ITEMS: &[&str] = &[
    "apples", "pencils", "marbles", "stickers", "books", "coins", "cookies", "cards", "shells",
    "stamps", "buttons", "beads",
];

/// One word problem + chain-of-thought solution.
pub fn document(rng: &mut Pcg64) -> String {
    match rng.gen_index(3) {
        0 => buy_sell(rng),
        1 => share_equally(rng),
        _ => rate_time(rng),
    }
}

fn buy_sell(rng: &mut Pcg64) -> String {
    let name = rng.choose(FIRST_NAMES);
    let item = rng.choose(ITEMS);
    let start = 10 + rng.gen_range(90);
    let bought = 1 + rng.gen_range(40);
    let given = 1 + rng.gen_range(start.min(40));
    let total = start + bought - given;
    format!(
        "Question: {name} has {start} {item}. {name} buys {bought} more {item} and then \
         gives away {given}. How many {item} does {name} have now?\n\
         Solution: Start with {start} {item}. After buying {bought} more, {name} has \
         {start} + {bought} = {sum} {item}. After giving away {given}, the total is \
         {sum} - {given} = {total}. The answer is {total}.",
        sum = start + bought,
    )
}

fn share_equally(rng: &mut Pcg64) -> String {
    let name = rng.choose(FIRST_NAMES);
    let friend = rng.choose(FIRST_NAMES);
    let item = rng.choose(ITEMS);
    let groups = 2 + rng.gen_range(8);
    let per = 2 + rng.gen_range(20);
    let total = groups * per;
    format!(
        "Question: {name} and {friend} collected {total} {item} and shared them equally \
         among {groups} boxes. How many {item} are in each box?\n\
         Solution: Dividing {total} {item} into {groups} equal boxes gives \
         {total} / {groups} = {per} {item} per box. The answer is {per}.",
    )
}

fn rate_time(rng: &mut Pcg64) -> String {
    let name = rng.choose(FIRST_NAMES);
    let rate = 2 + rng.gen_range(18);
    let hours = 2 + rng.gen_range(10);
    let total = rate * hours;
    format!(
        "Question: A machine operated by {name} produces {rate} parts per hour. \
         How many parts does it produce in {hours} hours?\n\
         Solution: The machine produces {rate} parts each hour for {hours} hours, so the \
         total is {rate} * {hours} = {total} parts. The answer is {total}.",
    )
}

/// QA-formatted variant for the instruction corpus.
pub fn qa(rng: &mut Pcg64) -> (String, String) {
    let doc = document(rng);
    let (q, s) = doc.split_once("\nSolution: ").expect("document format");
    (q.trim_start_matches("Question: ").to_string(), s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Extract "The answer is N." and recompute from the question text.
    #[test]
    fn answers_are_arithmetically_consistent() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..100 {
            let d = document(&mut rng);
            assert!(d.contains("The answer is "), "{d}");
            // All equations of the form "a + b = c", "a - b = c", etc. hold.
            for part in d.split(". ") {
                check_equations(part);
            }
        }
    }

    fn check_equations(text: &str) {
        // crude parser for "x OP y = z"
        let words: Vec<&str> = text.split_whitespace().collect();
        for w in words.windows(5) {
            let (Ok(a), op, Ok(b), eq, Ok(c)) = (
                w[0].parse::<i64>(),
                w[1],
                w[2].parse::<i64>(),
                w[3],
                w[4].trim_end_matches(['.', ',']).parse::<i64>(),
            ) else {
                continue;
            };
            if eq != "=" {
                continue;
            }
            let got = match op {
                "+" => a + b,
                "-" => a - b,
                "*" => a * b,
                "/" => {
                    assert_eq!(a % b, 0, "{text}");
                    a / b
                }
                _ => continue,
            };
            assert_eq!(got, c, "bad equation in: {text}");
        }
    }

    #[test]
    fn qa_splits_cleanly() {
        let mut rng = Pcg64::seeded(2);
        let (q, a) = qa(&mut rng);
        assert!(q.ends_with('?'));
        assert!(a.contains("The answer is"));
    }
}
