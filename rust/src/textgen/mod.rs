//! Procedural text-corpus generators.
//!
//! These play the role of the paper's *human-generated* sources: the text
//! the LMs are pre-trained on, and the "human" side of the Fig 9
//! human-vs-LLM comparison. Each domain is a grammar/template generator
//! over curated word banks, seeded by the deterministic [`crate::util::Pcg64`]
//! so every corpus is reproducible bit-for-bit.
//!
//! Domains mirror the paper's eight evaluation datasets (§5.1.1):
//! wiki, article, code, math, clinical, web (movie reviews), science,
//! novel — plus the TPC-H `comment` field generator used by Table 2 and an
//! instruction/QA formatter used to build the "instruction tuning" corpus.

pub mod clinical;
pub mod code;
pub mod lexicon;
pub mod math;
pub mod novel;
pub mod science;
pub mod tpch;
pub mod web;
pub mod wiki;

use crate::util::Pcg64;

/// The eight evaluation domains of the paper plus TPC-H.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Domain {
    Wiki,
    Article,
    Code,
    Math,
    Clinical,
    Web,
    Science,
    Novel,
    Tpch,
}

impl Domain {
    /// The paper's eight evaluation datasets, in Table 5 column order.
    pub const EVAL: [Domain; 8] = [
        Domain::Wiki,
        Domain::Code,
        Domain::Math,
        Domain::Clinical,
        Domain::Web,
        Domain::Science,
        Domain::Novel,
        Domain::Article,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Domain::Wiki => "wiki",
            Domain::Article => "article",
            Domain::Code => "code",
            Domain::Math => "math",
            Domain::Clinical => "clinical",
            Domain::Web => "web",
            Domain::Science => "science",
            Domain::Novel => "novel",
            Domain::Tpch => "tpch",
        }
    }

    pub fn from_name(name: &str) -> crate::Result<Domain> {
        Ok(match name {
            "wiki" => Domain::Wiki,
            "article" => Domain::Article,
            "code" => Domain::Code,
            "math" => Domain::Math,
            "clinical" => Domain::Clinical,
            "web" => Domain::Web,
            "science" => Domain::Science,
            "novel" => Domain::Novel,
            "tpch" => Domain::Tpch,
            other => anyhow::bail!("unknown domain '{other}'"),
        })
    }

    /// Stable index used for the LM's domain-tag tokens.
    pub fn index(&self) -> usize {
        match self {
            Domain::Wiki => 0,
            Domain::Article => 1,
            Domain::Code => 2,
            Domain::Math => 3,
            Domain::Clinical => 4,
            Domain::Web => 5,
            Domain::Science => 6,
            Domain::Novel => 7,
            Domain::Tpch => 8,
        }
    }
}

/// Generate at least `min_bytes` of domain text (cut at a document
/// boundary, so output may slightly exceed `min_bytes`).
pub fn generate(domain: Domain, min_bytes: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::new(seed, domain.index() as u64 + 100);
    let mut out = Vec::with_capacity(min_bytes + 1024);
    while out.len() < min_bytes {
        let doc = match domain {
            Domain::Wiki => wiki::document(&mut rng),
            Domain::Article => wiki::abstract_doc(&mut rng),
            Domain::Code => code::document(&mut rng),
            Domain::Math => math::document(&mut rng),
            Domain::Clinical => clinical::document(&mut rng),
            Domain::Web => web::document(&mut rng),
            Domain::Science => science::document(&mut rng),
            Domain::Novel => novel::document(&mut rng),
            Domain::Tpch => tpch::comment(&mut rng),
        };
        out.extend_from_slice(doc.as_bytes());
        out.push(b'\n');
    }
    out
}

/// Generate an instruction-tuning style QA document (used to fine-tune the
/// `-instruct` model variants and as QA-structured eval data).
pub fn generate_qa(min_bytes: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::new(seed, 50);
    let mut out = Vec::with_capacity(min_bytes + 1024);
    while out.len() < min_bytes {
        let (q, a) = match rng.gen_index(3) {
            0 => math::qa(&mut rng),
            1 => science::qa(&mut rng),
            _ => wiki::qa(&mut rng),
        };
        out.extend_from_slice(b"Q: ");
        out.extend_from_slice(q.as_bytes());
        out.extend_from_slice(b"\nA: ");
        out.extend_from_slice(a.as_bytes());
        out.extend_from_slice(b"\n\n");
    }
    out
}

/// A small mixed-domain sample for unit tests.
pub fn quick_sample(min_bytes: usize, seed: u64) -> Vec<u8> {
    let mut out = Vec::new();
    let per = min_bytes / 3 + 1;
    out.extend(generate(Domain::Wiki, per, seed));
    out.extend(generate(Domain::Code, per, seed + 1));
    out.extend(generate(Domain::Math, per, seed + 2));
    out.truncate(min_bytes);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_domains_generate() {
        for d in Domain::EVAL.iter().chain([&Domain::Tpch]) {
            let text = generate(*d, 4000, 7);
            assert!(text.len() >= 4000, "{}", d.name());
            assert!(text.is_ascii(), "{} must be ASCII", d.name());
            // Should be text, not binary: high printable fraction.
            let printable =
                text.iter().filter(|&&b| (0x20..0x7F).contains(&b) || b == b'\n').count();
            assert!(printable as f64 / text.len() as f64 > 0.999, "{}", d.name());
        }
    }

    #[test]
    fn generation_is_deterministic() {
        for d in [Domain::Wiki, Domain::Code, Domain::Tpch] {
            assert_eq!(generate(d, 2000, 3), generate(d, 2000, 3));
            assert_ne!(generate(d, 2000, 3), generate(d, 2000, 4));
        }
    }

    #[test]
    fn domains_are_distinct() {
        let a = generate(Domain::Wiki, 2000, 1);
        let b = generate(Domain::Code, 2000, 1);
        assert_ne!(a, b);
    }

    #[test]
    fn qa_format() {
        let text = generate_qa(3000, 5);
        let s = String::from_utf8(text).unwrap();
        assert!(s.contains("Q: "));
        assert!(s.contains("\nA: "));
    }

    #[test]
    fn name_roundtrip() {
        for d in Domain::EVAL.iter().chain([&Domain::Tpch]) {
            assert_eq!(Domain::from_name(d.name()).unwrap(), *d);
        }
        assert!(Domain::from_name("bogus").is_err());
    }

    #[test]
    fn char_entropy_is_text_like() {
        // The paper's Table 2 reports ~4.3-4.7 bits/char for natural text;
        // our generators should land in a text-like band (3.5-5.2).
        for d in [Domain::Wiki, Domain::Novel, Domain::Clinical] {
            let text = generate(d, 60_000, 11);
            let mut counts = [0u64; 256];
            for &b in &text {
                counts[b as usize] += 1;
            }
            let total = text.len() as f64;
            let h: f64 = counts
                .iter()
                .filter(|&&c| c > 0)
                .map(|&c| {
                    let p = c as f64 / total;
                    -p * p.log2()
                })
                .sum();
            assert!((3.5..5.2).contains(&h), "{}: H={h}", d.name());
        }
    }
}
