//! Long-form narrative travel prose (the paper's Novel dataset is a
//! LongWriter-generated travel book).

use super::lexicon::{capitalize, FIRST_NAMES, PLACE_NAMES};
use crate::util::Pcg64;

const SCENES: &[&str] = &[
    "the old harbor", "a crowded market", "the northern quarter", "a quiet courtyard",
    "the railway station", "an abandoned lighthouse", "the riverside promenade",
    "a hillside vineyard", "the cathedral square", "a roadside inn",
];

const WEATHER: &[&str] = &[
    "under a thin morning fog", "in the amber light of late afternoon", "as rain gathered inland",
    "beneath a sky the color of slate", "while gulls argued overhead", "in the still heat of noon",
];

const ACTIONS: &[&str] = &[
    "lingered over coffee", "traded stories with a fisherman", "sketched the rooflines",
    "followed the sound of bells", "bargained for dried figs", "read the old inscriptions",
    "watched the ferries cross", "walked until the streets narrowed",
];

const REFLECTIONS: &[&str] = &[
    "Travel, I have come to believe, is mostly the art of paying attention.",
    "Every city keeps one honest street, if you walk far enough to find it.",
    "The guidebooks are wrong about distances and right about nothing else.",
    "A place reveals itself slowly, and only to the unhurried.",
    "Maps flatten what memory insists on keeping steep.",
];

/// One chapter fragment.
pub fn document(rng: &mut Pcg64) -> String {
    let place = rng.choose(PLACE_NAMES);
    let companion = rng.choose(FIRST_NAMES);
    let mut doc = format!(
        "Chapter {n}. We reached {place} {weather}, and made at once for {scene}. ",
        n = 1 + rng.gen_range(40),
        weather = rng.choose(WEATHER),
        scene = rng.choose(SCENES),
    );
    for _ in 0..2 + rng.gen_index(4) {
        match rng.gen_index(3) {
            0 => doc.push_str(&format!(
                "{companion} {action} {weather}. ",
                action = rng.choose(ACTIONS),
                weather = rng.choose(WEATHER),
            )),
            1 => doc.push_str(&format!(
                "We {action}, then crossed toward {scene}. ",
                action = rng.choose(ACTIONS),
                scene = rng.choose(SCENES),
            )),
            _ => doc.push_str(&format!(
                "{} ",
                capitalize(rng.choose(REFLECTIONS)),
            )),
        }
    }
    doc.push_str(rng.choose(REFLECTIONS));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chapter_structure() {
        let mut rng = Pcg64::seeded(1);
        let d = document(&mut rng);
        assert!(d.starts_with("Chapter "));
        assert!(d.len() > 120);
    }

    #[test]
    fn narrative_vocabulary_present() {
        let mut rng = Pcg64::seeded(2);
        let mut all = String::new();
        for _ in 0..30 {
            all.push_str(&document(&mut rng));
        }
        assert!(SCENES.iter().filter(|s| all.contains(*s)).count() >= 5);
        assert!(REFLECTIONS.iter().filter(|s| all.contains(*s)).count() >= 3);
    }
}
