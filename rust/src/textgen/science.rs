//! Physics problem–solution pairs (the paper's Science dataset is
//! CAMEL-physics: GPT-4 problem/solution pairs over 25 physics topics).
//! Numeric answers are computed so the text is internally consistent.

use crate::util::Pcg64;

const TOPICS: &[&str] = &[
    "kinematics", "dynamics", "thermodynamics", "electrostatics", "optics", "fluid mechanics",
    "rotational motion", "simple harmonic motion", "wave propagation", "circuits",
];

/// One problem–solution document.
pub fn document(rng: &mut Pcg64) -> String {
    let topic = rng.choose(TOPICS);
    let (problem, solution) = match rng.gen_index(3) {
        0 => velocity(rng),
        1 => ohms_law(rng),
        _ => kinetic_energy(rng),
    };
    format!("Topic: {topic}\nProblem: {problem}\nSolution: {solution}")
}

fn velocity(rng: &mut Pcg64) -> (String, String) {
    let d = 10 * (1 + rng.gen_range(50));
    let t = 1 + rng.gen_range(20);
    let v = d as f64 / t as f64;
    (
        format!(
            "A vehicle travels {d} meters in {t} seconds at constant speed. \
             What is its velocity?"
        ),
        format!(
            "Velocity is distance divided by time: v = d / t = {d} / {t} = {v:.2} m/s. \
             Therefore the velocity is {v:.2} m/s."
        ),
    )
}

fn ohms_law(rng: &mut Pcg64) -> (String, String) {
    let r = 2 + rng.gen_range(98);
    let i = 1 + rng.gen_range(12);
    let v = r * i;
    (
        format!(
            "A resistor of {r} ohms carries a current of {i} amperes. \
             What is the voltage across the resistor?"
        ),
        format!(
            "By Ohm's law, V = I * R = {i} * {r} = {v} volts. \
             Therefore the voltage across the resistor is {v} V."
        ),
    )
}

fn kinetic_energy(rng: &mut Pcg64) -> (String, String) {
    let m = 1 + rng.gen_range(40);
    let v = 2 * (1 + rng.gen_range(15));
    let ke = m * v * v / 2;
    (
        format!(
            "An object of mass {m} kilograms moves at {v} meters per second. \
             What is its kinetic energy?"
        ),
        format!(
            "Kinetic energy is KE = (1/2) m v^2 = 0.5 * {m} * {v}^2 = {ke} joules. \
             Therefore the kinetic energy is {ke} J."
        ),
    )
}

/// QA pair for the instruction corpus.
pub fn qa(rng: &mut Pcg64) -> (String, String) {
    let doc = document(rng);
    let p = doc.split("\nProblem: ").nth(1).unwrap();
    let (q, s) = p.split_once("\nSolution: ").unwrap();
    (q.to_string(), s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn document_layout() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..20 {
            let d = document(&mut rng);
            assert!(d.starts_with("Topic: "));
            assert!(d.contains("\nProblem: "));
            assert!(d.contains("\nSolution: "));
        }
    }

    #[test]
    fn ohms_law_consistent() {
        let mut rng = Pcg64::seeded(2);
        for _ in 0..50 {
            let (_, s) = ohms_law(&mut rng);
            // "V = I * R = i * r = v volts"
            let nums: Vec<i64> = s
                .split(|c: char| !c.is_ascii_digit())
                .filter(|t| !t.is_empty())
                .map(|t| t.parse().unwrap())
                .collect();
            // nums = [i, r, v, v]
            assert_eq!(nums[0] * nums[1], nums[2]);
            assert_eq!(nums[2], nums[3]);
        }
    }

    #[test]
    fn qa_extraction() {
        let mut rng = Pcg64::seeded(3);
        let (q, a) = qa(&mut rng);
        assert!(q.ends_with('?'));
        assert!(a.contains("Therefore"));
    }
}
