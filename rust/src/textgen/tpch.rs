//! TPC-H `comment`-field generator, faithful to dbgen's grammar.
//!
//! dbgen builds comment text from a fixed phrase grammar: noun/verb/
//! adjective/adverb/preposition word lists combined into short clauses with
//! no discourse structure — which is why the paper's Table 2 measures very
//! low mutual information for TPC-H. We reproduce the word lists (a
//! representative subset of dbgen's) and the clause shapes.

use crate::util::Pcg64;

const NOUNS: &[&str] = &[
    "packages", "requests", "accounts", "deposits", "foxes", "ideas", "theodolites", "pinto beans",
    "instructions", "dependencies", "excuses", "platelets", "asymptotes", "courts", "dolphins",
    "multipliers", "sauternes", "warthogs", "frets", "dinos", "attainments", "braids", "grouches",
];

const VERBS: &[&str] = &[
    "sleep", "wake", "are", "cajole", "haggle", "nag", "use", "boost", "affix", "detect", "integrate",
    "maintain", "nod", "was", "lose", "sublate", "solve", "thrash", "promise", "engage", "hinder",
];

const ADJECTIVES: &[&str] = &[
    "furious", "sly", "careful", "blithe", "quick", "fluffy", "slow", "quiet", "ruthless", "thin",
    "close", "dogged", "daring", "bold", "regular", "final", "ironic", "even", "bold", "silent",
];

const ADVERBS: &[&str] = &[
    "sometimes", "always", "never", "furiously", "slyly", "carefully", "blithely", "quickly",
    "fluffily", "slowly", "quietly", "ruthlessly", "thinly", "closely", "doggedly", "daringly",
    "boldly", "regularly", "finally", "ironically", "evenly", "silently",
];

const PREPOSITIONS: &[&str] = &[
    "about", "above", "according to", "across", "after", "against", "along", "alongside of",
    "among", "around", "at", "atop", "before", "behind", "beneath", "beside", "besides",
    "between", "beyond", "by", "despite", "during", "except", "for", "from", "in place of",
    "inside", "instead of", "into", "near", "of", "on", "outside", "over", "past", "since",
    "through", "throughout", "to", "toward", "under", "until", "up", "upon", "without", "with",
];

const AUXILIARIES: &[&str] = &[
    "do", "may", "might", "shall", "will", "would", "can", "could", "should", "ought to",
    "must", "will have to", "shall have to", "could have to",
];

const TERMINATORS: &[&str] = &[".", ";", ":", "?", "!", "--"];

fn noun_phrase(rng: &mut Pcg64) -> String {
    match rng.gen_index(4) {
        0 => rng.choose(NOUNS).to_string(),
        1 => format!("{} {}", rng.choose(ADJECTIVES), rng.choose(NOUNS)),
        2 => format!("{}, {} {}", rng.choose(ADJECTIVES), rng.choose(ADJECTIVES), rng.choose(NOUNS)),
        _ => format!("{} {}", rng.choose(ADVERBS), rng.choose(ADJECTIVES)),
    }
}

fn verb_phrase(rng: &mut Pcg64) -> String {
    match rng.gen_index(4) {
        0 => rng.choose(VERBS).to_string(),
        1 => format!("{} {}", rng.choose(AUXILIARIES), rng.choose(VERBS)),
        2 => format!("{} {}", rng.choose(VERBS), rng.choose(ADVERBS)),
        _ => format!("{} {} {}", rng.choose(AUXILIARIES), rng.choose(VERBS), rng.choose(ADVERBS)),
    }
}

/// One dbgen-style comment sentence (grammar: `np vp [pp np] term`).
pub fn comment(rng: &mut Pcg64) -> String {
    let mut s = format!("{} {}", noun_phrase(rng), verb_phrase(rng));
    if rng.gen_bool(0.5) {
        s.push(' ');
        s.push_str(rng.choose(PREPOSITIONS));
        s.push_str(" the ");
        s.push_str(&noun_phrase(rng));
    }
    s.push_str(rng.choose(TERMINATORS));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_short_clauses() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..100 {
            let c = comment(&mut rng);
            assert!(c.len() < 120, "{c}");
            assert!(TERMINATORS.iter().any(|t| c.ends_with(t)), "{c}");
        }
    }

    #[test]
    fn low_structure_signature() {
        // dbgen comments have near-random word adjacency; check that the
        // bigram diversity is high relative to text with discourse structure.
        let mut rng = Pcg64::seeded(2);
        let mut text = String::new();
        for _ in 0..2000 {
            text.push_str(&comment(&mut rng));
            text.push(' ');
        }
        let words: Vec<&str> = text.split_whitespace().collect();
        let uniq_bigrams: std::collections::HashSet<(&str, &str)> =
            words.windows(2).map(|w| (w[0], w[1])).collect();
        let diversity = uniq_bigrams.len() as f64 / (words.len() - 1) as f64;
        assert!(diversity > 0.2, "diversity {diversity}");
    }
}
