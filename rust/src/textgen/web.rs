//! Movie-review web content (the paper's Web dataset: ChatGPT-written movie
//! critiques mimicking human reviews; the human counterpart in Fig 9 is
//! imdb). One generator, two registers: `document` (polished, LLM-ish) and
//! `imdb_style` (colloquial, typo-prone "human" reviews for Fig 9).

use super::lexicon::{FIRST_NAMES, PERSON_NAMES};
use crate::util::Pcg64;

const GENRES: &[&str] = &[
    "thriller", "drama", "comedy", "science fiction epic", "heist film", "romance",
    "documentary", "western", "mystery", "animated feature",
];

const ASPECTS: &[&str] = &[
    "the cinematography", "the pacing", "the screenplay", "the ensemble cast", "the score",
    "the production design", "the editing", "the dialogue", "the third act", "the direction",
];

const PRAISE: &[&str] = &[
    "is nothing short of remarkable", "carries the film effortlessly", "rewards patient viewers",
    "elevates familiar material", "strikes a confident balance", "deserves genuine applause",
];

const CRITIQUE: &[&str] = &[
    "never quite finds its rhythm", "buckles under its own ambition", "feels curiously inert",
    "tests the audience's patience", "settles for easy answers", "drifts in the second hour",
];

const TITLES_A: &[&str] =
    &["The Last", "A Quiet", "Midnight", "The Glass", "Echoes of", "Beyond the", "The Paper"];
const TITLES_B: &[&str] =
    &["Harbor", "Orchard", "Signal", "Divide", "Horizon", "Labyrinth", "Reckoning", "Garden"];

fn title(rng: &mut Pcg64) -> String {
    format!("{} {}", rng.choose(TITLES_A), rng.choose(TITLES_B))
}

/// Polished critic review (the LLM-register Web dataset).
pub fn document(rng: &mut Pcg64) -> String {
    let t = title(rng);
    let genre = rng.choose(GENRES);
    let director = rng.choose(PERSON_NAMES);
    let stars = 1 + rng.gen_range(5);
    let mut doc = format!(
        "Review: \"{t}\" ({y}) -- {stars}/5 stars.\n\
         {director}'s new {genre} opens with a sequence that announces its intentions clearly. ",
        y = 1985 + rng.gen_range(40),
    );
    for _ in 0..2 + rng.gen_index(3) {
        let aspect = rng.choose(ASPECTS);
        let verdict =
            if stars >= 3 { rng.choose(PRAISE) } else { rng.choose(CRITIQUE) };
        doc.push_str(&format!("As for {aspect}, it {verdict}. "));
    }
    doc.push_str(&format!(
        "In the end, \"{t}\" {verdict}, and audiences seeking a {genre} will find \
         {closing}.",
        verdict = if stars >= 3 { rng.choose(PRAISE) } else { rng.choose(CRITIQUE) },
        closing = if stars >= 3 { "plenty to admire" } else { "little to hold onto" },
    ));
    doc
}

const COLLOQUIAL: &[&str] = &[
    "honestly", "not gonna lie", "imo", "tbh", "no spoilers but", "ok so", "look,",
];

const HUMAN_VERDICTS: &[&str] = &[
    "i loved it", "kinda dragged", "totally worth it", "meh", "blew me away",
    "save your money", "best thing i've seen all year", "i wanted to like it",
];

/// Colloquial imdb-style review (the "human" register for Fig 9).
pub fn imdb_style(rng: &mut Pcg64) -> String {
    let t = title(rng);
    let name = rng.choose(FIRST_NAMES);
    let mut doc = format!(
        "{lead} watched \"{t}\" last {day} and {verdict}. ",
        lead = super::lexicon::capitalize(rng.choose(COLLOQUIAL)),
        day = rng.choose(&["night", "weekend", "tuesday", "week"]),
        verdict = rng.choose(HUMAN_VERDICTS),
    );
    for _ in 0..1 + rng.gen_index(3) {
        doc.push_str(&format!(
            "{c} {aspect} {v}... {verdict2}. ",
            c = rng.choose(COLLOQUIAL),
            aspect = rng.choose(ASPECTS),
            v = rng.choose(&["was something else", "did NOT work for me", "was fine i guess",
                "deserves an oscar", "was all over the place"]),
            verdict2 = rng.choose(HUMAN_VERDICTS),
        ));
    }
    doc.push_str(&format!("{}/10 from me ({name})", 1 + rng.gen_range(10)));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn review_structure() {
        let mut rng = Pcg64::seeded(1);
        let d = document(&mut rng);
        assert!(d.starts_with("Review: \""));
        assert!(d.contains("/5 stars"));
    }

    #[test]
    fn imdb_register_differs() {
        let mut rng = Pcg64::seeded(2);
        let d = imdb_style(&mut rng);
        assert!(d.contains("/10 from me"));
        // Register check: colloquial markers appear.
        assert!(COLLOQUIAL.iter().any(|c| d.to_lowercase().contains(c)));
    }

    #[test]
    fn registers_produce_different_text() {
        let mut a = Pcg64::seeded(3);
        let mut b = Pcg64::seeded(3);
        assert_ne!(document(&mut a), imdb_style(&mut b));
    }
}
