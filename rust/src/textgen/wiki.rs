//! Wikipedia-style introductions (the paper's Wiki dataset) and scientific
//! abstracts (the Article dataset), plus encyclopedic QA pairs.

use super::lexicon::{self, capitalize, paragraph, year, PERSON_NAMES, PLACE_NAMES};
use crate::util::Pcg64;

const TOPICS: &[&str] = &[
    "settlement", "river", "mountain range", "cathedral", "university", "railway", "festival",
    "dynasty", "observatory", "harbor", "province", "museum", "bridge", "monastery", "canal",
];

const WIKI_NOUNS: &[&str] = &[
    "territory", "census", "district", "municipality", "heritage", "architecture", "trade",
    "settlement", "expansion", "restoration", "administration", "jurisdiction",
];

const WIKI_ADJS: &[&str] = &[
    "historic", "medieval", "industrial", "coastal", "rural", "urban", "agricultural",
    "administrative", "cultural", "regional",
];

/// One Wikipedia-style introduction.
pub fn document(rng: &mut Pcg64) -> String {
    let place = rng.choose(PLACE_NAMES);
    let topic = rng.choose(TOPICS);
    let founded = year(rng);
    let pop = 1000 + rng.gen_range(900_000);
    let mut doc = format!(
        "{place} is a {adj} {topic} in the {region} region, first recorded in {founded}. ",
        adj = rng.choose(WIKI_ADJS),
        region = rng.choose(PLACE_NAMES),
    );
    doc.push_str(&format!(
        "As of the most recent census, the population of {place} was approximately {pop}. "
    ));
    let n_sent = 2 + rng.gen_index(3);
    doc.push_str(&paragraph(rng, n_sent, WIKI_NOUNS, WIKI_ADJS));
    if rng.gen_bool(0.5) {
        doc.push_str(&format!(
            " The {topic} was studied by {person} in {y}.",
            person = rng.choose(PERSON_NAMES),
            y = year(rng).max(founded),
        ));
    }
    doc
}

const FIELDS: &[&str] = &[
    "machine learning", "data management", "distributed systems", "computer architecture",
    "information retrieval", "signal processing", "computational biology", "program analysis",
];

const METHOD_NOUNS: &[&str] = &[
    "framework", "benchmark", "algorithm", "pipeline", "dataset", "evaluation", "prototype",
    "compression", "throughput", "latency", "baseline", "workload",
];

const METHOD_ADJS: &[&str] = &[
    "scalable", "efficient", "novel", "robust", "lightweight", "end-to-end", "adaptive",
    "lossless", "parallel", "state-of-the-art",
];

/// One scientific-abstract-style document (the Article dataset).
pub fn abstract_doc(rng: &mut Pcg64) -> String {
    let field = rng.choose(FIELDS);
    let gain = 2 + rng.gen_range(30);
    let mut doc = format!(
        "Abstract. We present a {adj} {noun} for {field}. ",
        adj = rng.choose(METHOD_ADJS),
        noun = rng.choose(METHOD_NOUNS),
    );
    let n_sent = 2 + rng.gen_index(3);
    doc.push_str(&paragraph(rng, n_sent, METHOD_NOUNS, METHOD_ADJS));
    doc.push_str(&format!(
        " Experiments on {n} workloads show a {gain}x improvement over the {adj} baseline.",
        n = 3 + rng.gen_index(9),
        adj = rng.choose(METHOD_ADJS),
    ));
    doc
}

/// An encyclopedic QA pair for the instruction corpus.
pub fn qa(rng: &mut Pcg64) -> (String, String) {
    let place = rng.choose(PLACE_NAMES);
    let topic = rng.choose(TOPICS);
    let founded = year(rng);
    let q = format!("When was the {topic} of {place} first recorded?");
    let a = format!(
        "The {topic} of {place} was first recorded in {founded}. {rest}",
        rest = lexicon::sentence(rng, WIKI_NOUNS, WIKI_ADJS)
    );
    (capitalize(&q), a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wiki_document_structure() {
        let mut rng = Pcg64::seeded(1);
        let d = document(&mut rng);
        assert!(d.contains("population"));
        assert!(d.len() > 150);
    }

    #[test]
    fn abstract_has_headline_metric() {
        let mut rng = Pcg64::seeded(2);
        let d = abstract_doc(&mut rng);
        assert!(d.starts_with("Abstract."));
        assert!(d.contains("x improvement"));
    }

    #[test]
    fn qa_pair_nonempty() {
        let mut rng = Pcg64::seeded(3);
        let (q, a) = qa(&mut rng);
        assert!(q.ends_with('?'));
        assert!(!a.is_empty());
    }
}
