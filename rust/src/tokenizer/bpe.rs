//! Byte-pair encoding: trainer, encoder, decoder.
//!
//! Used by the analysis toolkit to reproduce the paper's Table 2 "BP-E"
//! (entropy per byte under subword tokenization). Classic Sennrich-style
//! BPE over bytes: repeatedly merge the most frequent adjacent pair.

use std::collections::HashMap;

/// A trained BPE model: 256 byte tokens + learned merges.
#[derive(Clone, Debug)]
pub struct Bpe {
    /// Merge rules in training order: (left, right) -> new token id.
    merges: Vec<(u32, u32)>,
    /// Rank lookup: (left, right) -> merge index.
    ranks: HashMap<(u32, u32), usize>,
    /// Token id -> byte expansion.
    expansions: Vec<Vec<u8>>,
}

impl Bpe {
    /// Train `n_merges` merges on `corpus`.
    pub fn train(corpus: &[u8], n_merges: usize) -> Self {
        let mut expansions: Vec<Vec<u8>> = (0..256u32).map(|b| vec![b as u8]).collect();
        let mut merges = Vec::with_capacity(n_merges);
        let mut ranks = HashMap::new();
        // Work on the token sequence directly (fine for analysis-scale data).
        let mut seq: Vec<u32> = corpus.iter().map(|&b| b as u32).collect();
        for _ in 0..n_merges {
            // Count adjacent pairs.
            let mut counts: HashMap<(u32, u32), u64> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // Most frequent pair, ties broken deterministically.
            let Some((&pair, &count)) =
                counts.iter().max_by_key(|(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if count < 2 {
                break;
            }
            let new_id = expansions.len() as u32;
            let mut exp = expansions[pair.0 as usize].clone();
            exp.extend_from_slice(&expansions[pair.1 as usize]);
            expansions.push(exp);
            ranks.insert(pair, merges.len());
            merges.push(pair);
            // Apply the merge to the working sequence.
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        Bpe { merges, ranks, expansions }
    }

    /// Vocabulary size (256 + number of merges).
    pub fn vocab_size(&self) -> usize {
        self.expansions.len()
    }

    pub fn num_merges(&self) -> usize {
        self.merges.len()
    }

    /// Byte expansion of a token.
    pub fn expansion(&self, token: u32) -> &[u8] {
        &self.expansions[token as usize]
    }

    /// Encode bytes by applying merges in rank order (lowest rank first),
    /// the standard greedy BPE encode.
    pub fn encode(&self, data: &[u8]) -> Vec<u32> {
        let mut seq: Vec<u32> = data.iter().map(|&b| b as u32).collect();
        loop {
            // Find the lowest-rank applicable pair.
            let mut best: Option<(usize, usize)> = None; // (rank, pos)
            for i in 0..seq.len().saturating_sub(1) {
                if let Some(&rank) = self.ranks.get(&(seq[i], seq[i + 1])) {
                    if best.map_or(true, |(r, _)| rank < r) {
                        best = Some((rank, i));
                    }
                }
            }
            let Some((rank, _)) = best else { break };
            let pair = self.merges[rank];
            let new_id = 256 + rank as u32;
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        seq
    }

    /// Decode tokens back to bytes.
    pub fn decode(&self, tokens: &[u32]) -> Vec<u8> {
        let mut out = Vec::new();
        for &t in tokens {
            out.extend_from_slice(self.expansion(t));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_corpus;

    #[test]
    fn roundtrip_lossless() {
        let corpus = test_corpus::textish(20_000, 1);
        let bpe = Bpe::train(&corpus, 200);
        for data in [&corpus[..1000], b"unseen bytes \xff\x00!", b""] {
            let toks = bpe.encode(data);
            assert_eq!(bpe.decode(&toks), data);
        }
    }

    #[test]
    fn merges_reduce_token_count() {
        let corpus = test_corpus::textish(20_000, 2);
        let bpe = Bpe::train(&corpus, 300);
        let toks = bpe.encode(&corpus);
        // Wordy text with 16 distinct words should compress well below 60%.
        assert!(toks.len() < corpus.len() * 6 / 10, "{} tokens", toks.len());
        assert!(bpe.vocab_size() > 256);
    }

    #[test]
    fn training_is_deterministic() {
        let corpus = test_corpus::textish(5_000, 3);
        let a = Bpe::train(&corpus, 50);
        let b = Bpe::train(&corpus, 50);
        assert_eq!(a.merges, b.merges);
    }

    #[test]
    fn no_merges_on_random_data() {
        // All pairs unique-ish: counts < 2 stops training early.
        let data: Vec<u8> = (0..255u8).collect();
        let bpe = Bpe::train(&data, 100);
        assert_eq!(bpe.num_merges(), 0);
        assert_eq!(bpe.encode(&data), data.iter().map(|&b| b as u32).collect::<Vec<_>>());
    }

    #[test]
    fn expansion_concatenation_invariant() {
        let corpus = b"the cat sat on the mat the cat sat on the mat".repeat(50);
        let bpe = Bpe::train(&corpus, 100);
        for t in 256..bpe.vocab_size() as u32 {
            let (l, r) = bpe.merges[(t - 256) as usize];
            let mut expect = bpe.expansion(l).to_vec();
            expect.extend_from_slice(bpe.expansion(r));
            assert_eq!(bpe.expansion(t), &expect[..]);
        }
    }
}
