//! Tokenizers.
//!
//! * [`vocab`] — the byte-level LM vocabulary shared with the Python side
//!   (256 raw bytes + special/domain tokens). This is the *model's*
//!   tokenizer; byte-level tokenization makes losslessness trivial (no
//!   out-of-vocabulary text exists).
//! * [`bpe`] — a byte-pair-encoding trainer/encoder/decoder used by the
//!   analysis toolkit for the paper's Table 2 "BP-E" entropy column.
//! * [`words`] — word/char segmentation used for W-E entropy and the
//!   mutual-information metric.

pub mod bpe;
pub mod vocab;
pub mod words;

pub use bpe::Bpe;
pub use vocab::{Vocab, BOS, DOMAIN_TAG_BASE, EOS, PAD, VOCAB_SIZE};
