//! The byte-level LM vocabulary — MUST stay in lockstep with
//! `python/compile/vocab.py` (the Python side asserts the same constants).
//!
//! Layout:
//! * `0..=255`   — raw bytes
//! * `256`       — PAD (never coded; fills fixed-shape batches)
//! * `257`       — BOS (chunk start)
//! * `258`       — EOS (generation stop)
//! * `259..=271` — domain tags (conditioning prefix for dataset generation)

/// Total vocabulary size (rounded to a multiple of 16 for MXU-friendly
/// projection shapes).
pub const VOCAB_SIZE: usize = 272;
pub const PAD: u32 = 256;
pub const BOS: u32 = 257;
pub const EOS: u32 = 258;
/// First domain-tag token id; domain `d` maps to `DOMAIN_TAG_BASE + d`.
pub const DOMAIN_TAG_BASE: u32 = 259;
/// Number of domain tags reserved.
pub const NUM_DOMAIN_TAGS: usize = 13;

/// Byte-level tokenizer for the LM.
#[derive(Clone, Copy, Debug, Default)]
pub struct Vocab;

impl Vocab {
    /// Encode raw bytes to token ids (identity + widen).
    pub fn encode(&self, data: &[u8]) -> Vec<u32> {
        data.iter().map(|&b| b as u32).collect()
    }

    /// Decode token ids back to bytes. Non-byte tokens are rejected — a
    /// lossless decode must never synthesize specials.
    pub fn decode(&self, tokens: &[u32]) -> crate::Result<Vec<u8>> {
        tokens
            .iter()
            .map(|&t| {
                if t < 256 {
                    Ok(t as u8)
                } else {
                    anyhow::bail!("non-byte token {t} in decode stream")
                }
            })
            .collect()
    }

    /// The domain-tag token for a domain index.
    pub fn domain_tag(&self, domain: usize) -> u32 {
        assert!(domain < NUM_DOMAIN_TAGS);
        DOMAIN_TAG_BASE + domain as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_constants() {
        assert_eq!(VOCAB_SIZE, 272);
        assert_eq!(PAD, 256);
        assert_eq!(BOS, 257);
        assert_eq!(EOS, 258);
        assert!(DOMAIN_TAG_BASE as usize + NUM_DOMAIN_TAGS <= VOCAB_SIZE);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let v = Vocab;
        let data: Vec<u8> = (0..=255).collect();
        let toks = v.encode(&data);
        assert_eq!(v.decode(&toks).unwrap(), data);
    }

    #[test]
    fn specials_rejected_in_decode() {
        let v = Vocab;
        assert!(v.decode(&[65, PAD]).is_err());
        assert!(v.decode(&[BOS]).is_err());
    }

    #[test]
    fn domain_tags_in_range() {
        let v = Vocab;
        for d in 0..NUM_DOMAIN_TAGS {
            let t = v.domain_tag(d);
            assert!((t as usize) < VOCAB_SIZE);
            assert!(t >= DOMAIN_TAG_BASE);
        }
    }
}
