//! Word and character segmentation for the analysis metrics (Table 2's
//! Char-E / W-E columns and the mutual-information measure, Fig 2's n-grams).

/// Split text into word tokens: maximal runs of alphanumerics; punctuation
/// characters are their own tokens; whitespace separates.
pub fn words(text: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let bytes = text.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if c.is_ascii_whitespace() {
            i += 1;
        } else if c.is_ascii_alphanumeric() || c == '_' {
            let start = i;
            while i < bytes.len() && ((bytes[i] as char).is_ascii_alphanumeric() || bytes[i] == b'_')
            {
                i += 1;
            }
            out.push(&text[start..i]);
        } else {
            // Punctuation / other: single-byte token (ASCII-safe corpora).
            let start = i;
            // Step over a full UTF-8 scalar to stay on char boundaries.
            let ch_len = text[start..].chars().next().map(|c| c.len_utf8()).unwrap_or(1);
            i += ch_len;
            out.push(&text[start..i]);
        }
    }
    out
}

/// Character tokens (unicode scalars).
pub fn chars(text: &str) -> Vec<char> {
    text.chars().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_words_and_punct() {
        let toks = words("The cat, the mat.");
        assert_eq!(toks, vec!["The", "cat", ",", "the", "mat", "."]);
    }

    #[test]
    fn handles_numbers_and_underscores() {
        let toks = words("x_1 = 42 + foo_bar");
        assert_eq!(toks, vec!["x_1", "=", "42", "+", "foo_bar"]);
    }

    #[test]
    fn empty_and_whitespace() {
        assert!(words("").is_empty());
        assert!(words("   \n\t ").is_empty());
    }

    #[test]
    fn utf8_punctuation_safe() {
        let toks = words("café — test");
        // 'é' is non-ascii-alphanumeric: becomes its own token; the point is
        // no panic on char boundaries.
        assert!(toks.contains(&"caf"));
        assert!(toks.contains(&"test"));
    }

    #[test]
    fn chars_counts_scalars() {
        assert_eq!(chars("abé").len(), 3);
    }
}
