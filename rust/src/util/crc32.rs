//! CRC-32 (IEEE 802.3 polynomial, reflected) — used by the compression
//! container format to verify lossless round-trips at decode time.

/// Lazily-built 8-entry-per-byte slicing table.
fn table() -> &'static [[u32; 256]; 8] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Box<[[u32; 256]; 8]>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = Box::new([[0u32; 256]; 8]);
        for i in 0..256u32 {
            let mut crc = i;
            for _ in 0..8 {
                crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            }
            t[0][i as usize] = crc;
        }
        for i in 0..256usize {
            for j in 1..8usize {
                let prev = t[j - 1][i];
                t[j][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            }
        }
        t
    })
}

/// Compute the CRC-32 of `data` (slicing-by-8).
pub fn crc32(data: &[u8]) -> u32 {
    !update_state(!0u32, data)
}

/// Streaming CRC-32: feed bytes in any number of [`Crc32::update`] calls;
/// [`Crc32::finalize`] equals [`crc32`] over the concatenation. Used by the
/// incremental compression paths, which never hold the whole input.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: !0u32 }
    }

    pub fn update(&mut self, data: &[u8]) {
        self.state = update_state(self.state, data);
    }

    /// Final CRC value; the accumulator stays usable (more updates extend
    /// the stream).
    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

/// Advance the raw (pre-inversion) CRC state over `data` (slicing-by-8).
fn update_state(mut crc: u32, data: &[u8]) -> u32 {
    let t = table();
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let lo = u32::from_le_bytes(c[0..4].try_into().unwrap()) ^ crc;
        let hi = u32::from_le_bytes(c[4..8].try_into().unwrap());
        crc = t[7][(lo & 0xFF) as usize]
            ^ t[6][((lo >> 8) & 0xFF) as usize]
            ^ t[5][((lo >> 16) & 0xFF) as usize]
            ^ t[4][(lo >> 24) as usize]
            ^ t[3][(hi & 0xFF) as usize]
            ^ t[2][((hi >> 8) & 0xFF) as usize]
            ^ t[1][((hi >> 16) & 0xFF) as usize]
            ^ t[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        crc = (crc >> 8) ^ t[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard CRC-32 test vectors.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_vs_slice_boundaries() {
        // Exercise the chunks_exact remainder path at every offset.
        let data: Vec<u8> = (0..64u8).collect();
        for len in 0..data.len() {
            let reference = {
                // bit-at-a-time reference implementation
                let mut crc = !0u32;
                for &b in &data[..len] {
                    crc ^= b as u32;
                    for _ in 0..8 {
                        crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
                    }
                }
                !crc
            };
            assert_eq!(crc32(&data[..len]), reference, "len={len}");
        }
    }

    #[test]
    fn streaming_matches_one_shot_for_any_split() {
        let data: Vec<u8> = (0..255u8).cycle().take(1000).collect();
        let want = crc32(&data);
        for split in [0usize, 1, 7, 8, 9, 500, 999, 1000] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&[]);
            c.update(&data[split..]);
            assert_eq!(c.finalize(), want, "split={split}");
        }
        // Many tiny updates.
        let mut c = Crc32::new();
        for b in &data {
            c.update(std::slice::from_ref(b));
        }
        assert_eq!(c.finalize(), want);
        assert_eq!(Crc32::new().finalize(), crc32(b""));
    }
}
