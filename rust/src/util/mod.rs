//! Shared utilities: deterministic RNG, CRC32, byte helpers, simple stats.

pub mod crc32;
pub mod pool;
pub mod rng;
pub mod stats;

pub use crc32::{crc32, Crc32};
pub use pool::{BytePool, PooledBuf, PoolStats};
pub use rng::Pcg64;

/// Integer log2 (floor). `msb(1) == 0`, `msb(255) == 7`.
#[inline]
pub fn floor_log2(x: u32) -> u32 {
    debug_assert!(x > 0);
    31 - x.leading_zeros()
}

/// Read a little-endian u32 from `buf[pos..pos+4]`.
#[inline]
pub fn read_u32_le(buf: &[u8], pos: usize) -> u32 {
    u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap())
}

/// Read a little-endian u64 from `buf[pos..pos+8]`.
#[inline]
pub fn read_u64_le(buf: &[u8], pos: usize) -> u64 {
    u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap())
}

/// Human-readable byte size, e.g. `1.50 MiB`.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_log2_basics() {
        assert_eq!(floor_log2(1), 0);
        assert_eq!(floor_log2(2), 1);
        assert_eq!(floor_log2(3), 1);
        assert_eq!(floor_log2(4), 2);
        assert_eq!(floor_log2(255), 7);
        assert_eq!(floor_log2(256), 8);
        assert_eq!(floor_log2(u32::MAX), 31);
    }

    #[test]
    fn human_bytes_format() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn read_le_roundtrip() {
        let buf = 0xDEADBEEFu32.to_le_bytes();
        assert_eq!(read_u32_le(&buf, 0), 0xDEADBEEF);
        let buf = 0x0123_4567_89AB_CDEFu64.to_le_bytes();
        assert_eq!(read_u64_le(&buf, 0), 0x0123_4567_89AB_CDEF);
    }
}
