//! Bounded recycling pool for byte buffers.
//!
//! The serve path allocates a fresh `Vec<u8>` at every hop today: wire
//! frame read, batcher hand-off, container assembly, response write. At
//! steady state those buffers are all the same few sizes, so the
//! allocations are pure churn. `BytePool` is a bounded free list of
//! `Vec<u8>` storage; `PooledBuf` is a `Vec<u8>` that returns its
//! storage to the pool on drop (the squashfs-rs `ParallelCompressor`
//! idiom: finished buffers go back to a bounded channel when the
//! response is dropped).
//!
//! Ownership contract (see `docs/zerocopy.md`):
//!
//! - A `PooledBuf` is an owned, mutable `Vec<u8>` — hold it as long as
//!   you like, send it across threads, grow it. Nothing is borrowed.
//! - Storage returns to the pool exactly once, on drop. `detach()`
//!   converts to a plain `Vec<u8>` and opts out of recycling.
//! - `Clone` makes a *detached* copy (the clone does not return to the
//!   pool); cloning is for the rare fan-out path, not the hot loop.
//! - When the pool is dry (or disabled via `LLMZIP_POOL=0`) `take()`
//!   falls back to a plain allocation; behavior is identical either
//!   way — pooling changes *where* bytes live, never their values.
//!
//! Std-only by design (vendored-offline dependency policy): the free
//! list is a `Mutex<Vec<Vec<u8>>>`, not a crossbeam channel. The lock
//! is held only to push/pop one pointer-sized element.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Upper bound on the capacity a recycled buffer may retain. Returning
/// a one-off 256 MB frame to the pool would pin that memory for the
/// life of the server; anything above this cap is dropped instead.
const MAX_RECYCLED_CAPACITY: usize = 8 << 20;

/// Counters exposed for tests and the allocation bench. All are
/// monotonically increasing totals since pool creation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// `take()` calls served from the free list.
    pub hits: u64,
    /// `take()` calls that fell back to a fresh allocation.
    pub misses: u64,
    /// Buffers accepted back into the free list on drop.
    pub returns: u64,
    /// Buffers dropped on return (pool full, oversized, or disabled).
    pub discards: u64,
}

struct Inner {
    free: Mutex<Vec<Vec<u8>>>,
    /// Maximum number of buffers the free list may hold.
    cap: usize,
    /// `false` when recycling is disabled (`LLMZIP_POOL=0` or
    /// `BytePool::disabled()`): every take allocates, every return
    /// discards. The `PooledBuf` type is still used so call sites
    /// don't branch.
    enabled: bool,
    hits: AtomicU64,
    misses: AtomicU64,
    returns: AtomicU64,
    discards: AtomicU64,
}

/// Cloneable handle to a shared bounded buffer pool.
#[derive(Clone)]
pub struct BytePool {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for BytePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("BytePool")
            .field("cap", &self.inner.cap)
            .field("enabled", &self.inner.enabled)
            .field("stats", &s)
            .finish()
    }
}

impl BytePool {
    /// Pool holding at most `cap` free buffers. Recycling is disabled
    /// when the `LLMZIP_POOL` environment variable is set to `0`
    /// (checked here, at construction, so a process can build both
    /// pooled and unpooled servers for A/B measurement).
    pub fn new(cap: usize) -> Self {
        let enabled = std::env::var("LLMZIP_POOL").map(|v| v != "0").unwrap_or(true);
        Self::with_enabled(cap, enabled)
    }

    /// Pool that never recycles: every take allocates, every return
    /// discards. Used for pooling-off A/B runs regardless of env.
    pub fn disabled() -> Self {
        Self::with_enabled(0, false)
    }

    /// Explicit on/off constructor (tests and benches want determinism
    /// independent of the environment).
    pub fn with_enabled(cap: usize, enabled: bool) -> Self {
        BytePool {
            inner: Arc::new(Inner {
                free: Mutex::new(Vec::with_capacity(cap.min(64))),
                cap,
                enabled,
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                returns: AtomicU64::new(0),
                discards: AtomicU64::new(0),
            }),
        }
    }

    /// Whether this pool recycles at all.
    pub fn is_enabled(&self) -> bool {
        self.inner.enabled
    }

    /// An empty buffer, recycled if the free list has one. The buffer
    /// always has `len() == 0`; `min_capacity` is a reservation hint so
    /// the first fill doesn't regrow.
    pub fn take(&self, min_capacity: usize) -> PooledBuf {
        if self.inner.enabled {
            let recycled = self.inner.free.lock().expect("pool lock").pop();
            if let Some(mut buf) = recycled {
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                buf.clear();
                if buf.capacity() < min_capacity {
                    buf.reserve(min_capacity - buf.capacity());
                }
                return PooledBuf { buf, pool: Some(self.clone()) };
            }
        }
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        let pool = if self.inner.enabled { Some(self.clone()) } else { None };
        PooledBuf { buf: Vec::with_capacity(min_capacity), pool }
    }

    /// Wrap an existing `Vec<u8>` so its storage recycles on drop.
    /// The contents are preserved.
    pub fn adopt(&self, buf: Vec<u8>) -> PooledBuf {
        let pool = if self.inner.enabled { Some(self.clone()) } else { None };
        PooledBuf { buf, pool }
    }

    /// Number of buffers currently on the free list.
    pub fn free_len(&self) -> usize {
        self.inner.free.lock().expect("pool lock").len()
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            returns: self.inner.returns.load(Ordering::Relaxed),
            discards: self.inner.discards.load(Ordering::Relaxed),
        }
    }

    fn give_back(&self, buf: Vec<u8>) {
        if !self.inner.enabled
            || buf.capacity() == 0
            || buf.capacity() > MAX_RECYCLED_CAPACITY
        {
            self.inner.discards.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut free = self.inner.free.lock().expect("pool lock");
        if free.len() < self.inner.cap {
            free.push(buf);
            drop(free);
            self.inner.returns.fetch_add(1, Ordering::Relaxed);
        } else {
            drop(free);
            self.inner.discards.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// An owned byte buffer whose storage returns to its `BytePool` on
/// drop. Derefs to `Vec<u8>`, so call sites read and mutate it exactly
/// like the plain vectors it replaces.
pub struct PooledBuf {
    buf: Vec<u8>,
    /// `None` for detached buffers (plain-alloc fallback, `From<Vec>`,
    /// clones): those just drop normally.
    pool: Option<BytePool>,
}

impl PooledBuf {
    /// A detached empty buffer (never recycles). Handy for tests and
    /// for call sites that construct payloads without a server pool.
    pub fn detached(buf: Vec<u8>) -> Self {
        PooledBuf { buf, pool: None }
    }

    /// Consume, returning the inner `Vec<u8>` and opting out of
    /// recycling (the storage now belongs to the caller for good).
    pub fn detach(mut self) -> Vec<u8> {
        self.pool = None;
        std::mem::take(&mut self.buf)
    }
}

impl std::ops::Deref for PooledBuf {
    type Target = Vec<u8>;
    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl std::ops::DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.give_back(std::mem::take(&mut self.buf));
        }
    }
}

impl Clone for PooledBuf {
    /// Clones are detached: only the original returns to the pool, so
    /// storage can never be recycled twice.
    fn clone(&self) -> Self {
        PooledBuf { buf: self.buf.clone(), pool: None }
    }
}

impl From<Vec<u8>> for PooledBuf {
    fn from(buf: Vec<u8>) -> Self {
        PooledBuf::detached(buf)
    }
}

impl std::fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledBuf")
            .field("len", &self.buf.len())
            .field("capacity", &self.buf.capacity())
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

impl PartialEq for PooledBuf {
    fn eq(&self, other: &Self) -> bool {
        self.buf == other.buf
    }
}
impl Eq for PooledBuf {}

impl AsRef<[u8]> for PooledBuf {
    fn as_ref(&self) -> &[u8] {
        &self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn take_and_drop_round_trips_capacity() {
        let pool = BytePool::with_enabled(4, true);
        let mut b = pool.take(1024);
        assert_eq!(b.len(), 0);
        assert!(b.capacity() >= 1024);
        b.extend_from_slice(&[7u8; 512]);
        let cap = b.capacity();
        drop(b);
        assert_eq!(pool.free_len(), 1);
        // The next take reuses the same storage (capacity preserved,
        // contents cleared).
        let b2 = pool.take(0);
        assert_eq!(b2.len(), 0);
        assert_eq!(b2.capacity(), cap);
        let s = pool.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.returns, 1);
    }

    #[test]
    fn dry_pool_falls_back_to_plain_alloc() {
        let pool = BytePool::with_enabled(2, true);
        let a = pool.take(16);
        let b = pool.take(16);
        let c = pool.take(16); // nothing returned yet: all three are misses
        assert_eq!(pool.stats().misses, 3);
        assert_eq!(pool.stats().hits, 0);
        drop(a);
        drop(b);
        drop(c); // cap is 2: third return is discarded
        assert_eq!(pool.free_len(), 2);
        assert_eq!(pool.stats().returns, 2);
        assert_eq!(pool.stats().discards, 1);
    }

    #[test]
    fn disabled_pool_never_recycles() {
        let pool = BytePool::with_enabled(8, false);
        let b = pool.take(64);
        drop(b);
        assert_eq!(pool.free_len(), 0);
        let s = pool.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 1);
        assert_eq!(s.returns, 0);
    }

    #[test]
    fn detach_opts_out_of_recycling() {
        let pool = BytePool::with_enabled(4, true);
        let mut b = pool.take(8);
        b.extend_from_slice(b"hello");
        let v = b.detach();
        assert_eq!(v, b"hello");
        drop(v);
        assert_eq!(pool.free_len(), 0, "detached storage must not return");
        assert_eq!(pool.stats().returns, 0);
    }

    #[test]
    fn clone_is_detached_and_returns_once() {
        let pool = BytePool::with_enabled(4, true);
        let mut b = pool.take(8);
        b.extend_from_slice(&[1, 2, 3]);
        let c = b.clone();
        assert_eq!(&*c, &[1, 2, 3]);
        drop(c);
        assert_eq!(pool.free_len(), 0, "clone must not return to the pool");
        drop(b);
        assert_eq!(pool.free_len(), 1, "original returns exactly once");
        assert_eq!(pool.stats().returns, 1);
    }

    #[test]
    fn adopt_preserves_contents_and_recycles() {
        let pool = BytePool::with_enabled(4, true);
        let b = pool.adopt(vec![9u8; 33]);
        assert_eq!(b.len(), 33);
        drop(b);
        assert_eq!(pool.free_len(), 1);
    }

    #[test]
    fn oversized_buffers_are_not_hoarded() {
        let pool = BytePool::with_enabled(4, true);
        let b = pool.adopt(Vec::with_capacity(MAX_RECYCLED_CAPACITY + 1));
        drop(b);
        assert_eq!(pool.free_len(), 0);
        assert_eq!(pool.stats().discards, 1);
    }

    /// Property test: a random interleaving of takes, writes, drops,
    /// detaches and clones keeps the free list within its cap, returns
    /// each pooled buffer at most once, and never corrupts contents.
    #[test]
    fn property_random_interleaving() {
        let mut rng = Pcg64::seeded(0xB0F1_57AA);
        for round in 0..50 {
            let cap = (rng.next_u64() % 5) as usize + 1;
            let pool = BytePool::with_enabled(cap, true);
            let mut live: Vec<(PooledBuf, Vec<u8>)> = Vec::new();
            let mut expected_returns = 0u64;
            for _ in 0..200 {
                match rng.next_u64() % 4 {
                    0 => {
                        // take + fill with a known pattern
                        let n = (rng.next_u64() % 2000) as usize;
                        let mut b = pool.take(n);
                        let fill: Vec<u8> =
                            (0..n).map(|i| (i as u8) ^ (round as u8)).collect();
                        b.extend_from_slice(&fill);
                        live.push((b, fill));
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = (rng.next_u64() as usize) % live.len();
                            let (b, want) = live.swap_remove(i);
                            assert_eq!(&*b, &want, "contents corrupted");
                            if b.capacity() > 0 && pool.free_len() < cap {
                                expected_returns += 1;
                            }
                            drop(b);
                        }
                    }
                    2 => {
                        if !live.is_empty() {
                            let i = (rng.next_u64() as usize) % live.len();
                            let (b, want) = live.swap_remove(i);
                            let v = b.detach();
                            assert_eq!(v, want);
                        }
                    }
                    _ => {
                        if let Some((b, want)) = live.last() {
                            let c = b.clone();
                            assert_eq!(&*c, want);
                        }
                    }
                }
                assert!(pool.free_len() <= cap, "free list exceeded cap");
            }
            drop(live);
            let s = pool.stats();
            assert!(pool.free_len() <= cap);
            assert!(
                s.returns >= expected_returns,
                "returns {} < lower bound {}",
                s.returns,
                expected_returns
            );
            // Conservation: every take either returned or discarded or
            // was detached/still-live; returns never exceed takes.
            assert!(s.returns <= s.hits + s.misses);
        }
    }
}
