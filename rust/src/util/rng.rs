//! Deterministic PRNG used everywhere randomness is needed (corpus
//! generation, sampling fallbacks, property tests, shuffles).
//!
//! We implement PCG64 (XSL-RR 128/64) so that every dataset, every training
//! corpus and every sampled token stream is reproducible bit-for-bit across
//! runs and machines — a hard requirement for a compression testbed where
//! "the data" must be identical between the compressor-under-test and the
//! recorded experiment.

/// PCG64 XSL-RR generator with 128-bit state.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MUL: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams with
    /// the same seed are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MUL).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MUL).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method.
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Pick an element from a slice by value (panics on empty slice).
    pub fn choose<T: Copy>(&mut self, items: &[T]) -> T {
        items[self.gen_index(items.len())]
    }

    /// Pick an index from explicit, not-necessarily-normalized weights.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_index(i + 1);
            items.swap(i, j);
        }
    }

    /// Fill a byte buffer with random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn different_streams_diverge() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_respects_bounds() {
        let mut rng = Pcg64::seeded(9);
        for bound in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(rng.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_is_roughly_uniform() {
        let mut rng = Pcg64::seeded(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.gen_index(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn choose_weighted_tracks_weights() {
        let mut rng = Pcg64::seeded(5);
        let weights = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.choose_weighted(&weights)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.5..3.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(13);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
