//! Tiny statistics helpers for benchmark reporting.

/// Online mean/min/max/count accumulator.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.sum_sq - self.sum * m) / (self.n - 1) as f64
    }

    pub fn stddev(&self) -> f64 {
        self.variance().max(0.0).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample (linear interpolation); `q` in [0, 1].
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (samples.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        samples[lo]
    } else {
        let frac = pos - lo as f64;
        samples[lo] * (1.0 - frac) + samples[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&mut v, 0.5) - 50.5).abs() < 1e-9);
        assert!((percentile(&mut v, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&mut v, 1.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&mut v, 0.99) - 99.01).abs() < 1e-9);
    }
}
