//! Cross-backend equivalence suite for the pluggable entropy stage.
//!
//! The fse backend (rank transform + tANS table coding) must be lossless
//! everywhere the adaptive range backend is — every textgen domain, both
//! weight precisions — produce byte-identical containers regardless of
//! execution shape, and interoperate with range-coded containers through
//! every decode face: one-shot, seekable, and the coordinator service
//! (including a MIXED fleet where the two sides are configured with
//! different codecs).

use llmzip::compress::rank::{byte_of_rank, rank_of};
use llmzip::compress::{Codec, Compressor, Container, LlmCompressor};
use llmzip::coordinator::{BatchPolicy, Server, ServerConfig};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use llmzip::textgen::{generate, Domain};
use llmzip::util::Pcg64;
use std::sync::Arc;
use std::time::Duration;

const CHUNK: usize = 64;
const LANES: usize = 4;

fn f32_compressor(codec: Codec) -> LlmCompressor {
    let cfg = by_name("nano").unwrap();
    LlmCompressor::from_weights(cfg, Weights::random(cfg, 99), CHUNK, LANES)
        .unwrap()
        .with_codec(codec)
}

fn int8_compressor(codec: Codec) -> LlmCompressor {
    let cfg = by_name("nano").unwrap();
    LlmCompressor::from_weights(cfg, Weights::random(cfg, 99).quantize(), CHUNK, LANES)
        .unwrap()
        .with_codec(codec)
}

/// Coordinator server over the same seed-99 weights, writing `codec`.
fn server_with_codec(codec: Codec, replicas: usize, threads: usize) -> Server {
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 99));
    Server::start(
        move || {
            LlmCompressor::from_shared(
                cfg,
                weights.clone(),
                llmzip::compress::LlmCompressorConfig {
                    model: cfg.name.into(),
                    chunk_tokens: CHUNK,
                    stream_bytes: 4 * CHUNK,
                    executor: llmzip::lm::ExecutorKind::Native,
                    lanes: LANES,
                    threads,
                    codec,
                    ..Default::default()
                },
            )
        },
        ServerConfig {
            chunk_tokens: CHUNK,
            replicas,
            threads,
            codec,
            policy: BatchPolicy { lanes: LANES, max_wait: Duration::from_millis(3) },
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn fse_is_lossless_on_every_domain_and_matches_range_output() {
    // The acceptance bar for the new backend: on all nine generator
    // domains, for f32 AND int8 weights, the fse container decodes to
    // exactly what the range container decodes to (the original bytes),
    // and each side's decoder accepts the other side's container.
    for (label, range_c, fse_c) in [
        ("f32", f32_compressor(Codec::Range), f32_compressor(Codec::Fse)),
        ("int8", int8_compressor(Codec::Range), int8_compressor(Codec::Fse)),
    ] {
        for domain in Domain::EVAL {
            let data = generate(domain, 700, 17);
            let zr = range_c.compress(&data).unwrap();
            let zf = fse_c.compress(&data).unwrap();
            assert_eq!(Codec::from_flags(Container::from_bytes(&zr).unwrap().flags), Codec::Range);
            assert_eq!(Codec::from_flags(Container::from_bytes(&zf).unwrap().flags), Codec::Fse);
            // Both backends are lossless...
            assert_eq!(range_c.decompress(&zr).unwrap(), data, "{label} {domain:?} range");
            assert_eq!(fse_c.decompress(&zf).unwrap(), data, "{label} {domain:?} fse");
            // ...and each decodes the OTHER's container (decode follows the
            // container's recorded codec, not the decoder's config).
            assert_eq!(range_c.decompress(&zf).unwrap(), data, "{label} {domain:?} cross r<-f");
            assert_eq!(fse_c.decompress(&zr).unwrap(), data, "{label} {domain:?} cross f<-r");
        }
    }
}

#[test]
fn rank_transform_is_self_inverse_on_model_cdfs() {
    // Suite-level restatement of the transform's core contract, over
    // random logit vectors rather than hand-built CDFs: rank_of and
    // byte_of_rank are exact inverses and the ranks are a permutation.
    let mut rng = Pcg64::seeded(23);
    for _ in 0..10 {
        let logits: Vec<f32> =
            (0..256).map(|_| (rng.gen_f64() * 16.0 - 8.0) as f32).collect();
        let (cdf, argmax) = llmzip::compress::llm::logits_to_cdf_argmax(&logits);
        assert_eq!(byte_of_rank(&cdf, argmax, 0) as usize, argmax);
        let mut seen = [false; 256];
        for sym in 0..256usize {
            let r = rank_of(&cdf, argmax, sym);
            assert!(!seen[r as usize]);
            seen[r as usize] = true;
            assert_eq!(byte_of_rank(&cdf, argmax, r) as usize, sym);
        }
    }
}

#[test]
fn fse_containers_byte_identical_across_server_shapes_and_direct_path() {
    // The byte-identity spine extends to the new backend: the coordinator
    // (any pool shape) and the direct single-engine path emit the same
    // fse container for the same input.
    let reference = f32_compressor(Codec::Fse);
    let data = generate(Domain::EVAL[2], 900, 31);
    let golden = reference.compress(&data).unwrap();
    for (replicas, threads) in [(1usize, 1usize), (2, 2)] {
        let server = server_with_codec(Codec::Fse, replicas, threads);
        let z = server.compress(&data).unwrap();
        assert_eq!(z, golden, "replicas={replicas} threads={threads}");
        assert_eq!(server.decompress(&golden).unwrap(), data);
    }
}

#[test]
fn mixed_codec_fleet_cross_decodes() {
    // A range-configured server decodes containers written by an
    // fse-configured server over the same engine, and vice versa — and
    // each stamps ITS codec on what it writes.
    let range_srv = server_with_codec(Codec::Range, 1, 1);
    let fse_srv = server_with_codec(Codec::Fse, 1, 1);
    let data = generate(Domain::EVAL[5], 800, 41);
    let zr = range_srv.compress(&data).unwrap();
    let zf = fse_srv.compress(&data).unwrap();
    assert_eq!(Codec::from_flags(Container::from_bytes(&zf).unwrap().flags), Codec::Fse);
    assert!(Container::from_bytes(&zf).unwrap().model_name.ends_with(":fse"));
    assert_eq!(fse_srv.decompress(&zr).unwrap(), data, "fse server <- range container");
    assert_eq!(range_srv.decompress(&zf).unwrap(), data, "range server <- fse container");
    // Empty input through the fse server still yields a valid, decodable
    // container stamped with the fse codec (the zero-chunk fast path).
    let z0 = fse_srv.compress(&[]).unwrap();
    assert_eq!(Codec::from_flags(Container::from_bytes(&z0).unwrap().flags), Codec::Fse);
    assert_eq!(range_srv.decompress(&z0).unwrap(), Vec::<u8>::new());
}

#[test]
fn fse_seekable_faces_match_range_faces() {
    // decompress_range / decode_chunk return the same slices from an fse
    // container as from the range container of the same input.
    let range_c = f32_compressor(Codec::Range);
    let fse_c = f32_compressor(Codec::Fse);
    let data = generate(Domain::EVAL[0], 1000, 53);
    let zr = range_c.compress(&data).unwrap();
    let zf = fse_c.compress(&data).unwrap();
    for (offset, len) in [(0u64, 64u64), (100, 300), (937, 63)] {
        let a = range_c.decompress_range(&zr, offset, len).unwrap();
        let b = range_c.decompress_range(&zf, offset, len).unwrap();
        assert_eq!(a, b, "range at {offset}+{len}");
        assert_eq!(a, data[offset as usize..(offset + len) as usize]);
    }
    let cr = Container::from_bytes(&zr).unwrap();
    let cf = Container::from_bytes(&zf).unwrap();
    assert_eq!(cr.chunks.len(), cf.chunks.len());
    for i in 0..cr.chunks.len() {
        assert_eq!(
            range_c.decode_chunk(&cr, i).unwrap(),
            fse_c.decode_chunk(&cf, i).unwrap(),
            "chunk {i}"
        );
    }
}
