//! Fleet property suite: a multi-model [`FleetServer`] must be invisible
//! in the bytes (every container identical to the direct single-compressor
//! path, for any mix of tenants, models, codecs and paging history) and
//! loud in its errors (unknown routes, rate limits, load shedding and
//! fingerprint drift all fail fast with clear messages — never a hang,
//! never a corrupt frame).

use llmzip::compress::{Codec, Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::wire::serve_connection;
use llmzip::coordinator::{
    BatchPolicy, FleetConfig, FleetModelSpec, FleetServer, ServerConfig, TenantSpec, WireService,
};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use llmzip::lm::{ExecutorKind, Precision};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

const CHUNK: usize = 64;

fn compressor_cfg(precision: Precision, codec: Codec) -> LlmCompressorConfig {
    LlmCompressorConfig {
        model: "nano".into(),
        chunk_tokens: CHUNK,
        stream_bytes: 256,
        executor: ExecutorKind::Native,
        lanes: 4,
        threads: 1,
        precision,
        codec,
        ..Default::default()
    }
}

fn spec(key: &str, precision: Precision, codec: Codec, seed: u64) -> FleetModelSpec {
    FleetModelSpec {
        key: key.to_string(),
        compressor: compressor_cfg(precision, codec),
        server: ServerConfig {
            chunk_tokens: CHUNK,
            codec,
            policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(2) },
            ..Default::default()
        },
        load: Arc::new(move || Ok(Weights::random(by_name("nano")?, seed))),
    }
}

/// The reference: a plain compressor built exactly like the fleet builds
/// its pool (same seed, precision, codec, chunking). Byte-identity of the
/// fleet path is always measured against THIS.
fn direct(precision: Precision, codec: Codec, seed: u64) -> LlmCompressor {
    let cfg = by_name("nano").unwrap();
    let weights = Weights::random(cfg, seed);
    let weights = match precision {
        Precision::Int8 => Arc::new(weights.quantize()),
        _ => Arc::new(weights),
    };
    LlmCompressor::from_shared(cfg, weights, compressor_cfg(precision, codec)).unwrap()
}

fn two_model_fleet(config: FleetConfig) -> Arc<FleetServer> {
    Arc::new(
        FleetServer::start(
            vec![
                spec("nano-f32", Precision::F32, Codec::Range, 7),
                spec("nano-int8", Precision::Int8, Codec::Fse, 8),
            ],
            config,
        )
        .unwrap(),
    )
}

fn spawn_listener(fleet: Arc<FleetServer>) -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        while let Ok((stream, _)) = listener.accept() {
            let fl = fleet.clone();
            std::thread::spawn(move || {
                let _ = serve_connection(stream, &*fl);
            });
        }
    });
    addr
}

#[test]
fn mixed_tenant_mixed_model_bursts_are_byte_identical_to_direct() {
    let fleet = two_model_fleet(FleetConfig {
        tenants: vec![
            TenantSpec {
                name: "alice".into(),
                weight: 3,
                rate_bytes_per_sec: 0.0,
                burst_bytes: 0.0,
            },
            TenantSpec { name: "bob".into(), weight: 1, rate_bytes_per_sec: 0.0, burst_bytes: 0.0 },
        ],
        ..Default::default()
    });
    let direct_f32 = direct(Precision::F32, Codec::Range, 7);
    let direct_int8 = direct(Precision::Int8, Codec::Fse, 8);
    let alice = fleet.bind_tenant("alice").unwrap();
    let bob = fleet.bind_tenant("bob").unwrap();
    assert_ne!(alice, bob);

    // A concurrent burst: both tenants hammer both models at once. Every
    // container that comes back must equal the direct path bit for bit —
    // tenancy, WFQ and routing may reorder WORK, never bytes.
    let handles: Vec<_> = (0..8u64)
        .map(|i| {
            let fl = fleet.clone();
            let tenant = if i % 2 == 0 { alice } else { bob };
            std::thread::spawn(move || {
                let data = llmzip::textgen::quick_sample(400 + (i as usize) * 97, i);
                let key = if i % 3 == 0 { "nano-int8" } else { "nano-f32" };
                let z = fl.compress_for(tenant, key, &data).unwrap();
                (key, data, z)
            })
        })
        .collect();
    for h in handles {
        let (key, data, z) = h.join().unwrap();
        let golden = match key {
            "nano-int8" => direct_int8.compress(&data).unwrap(),
            _ => direct_f32.compress(&data).unwrap(),
        };
        assert_eq!(z, golden, "fleet container differs from direct path on {key}");
        // Cross-decode: unrouted decompress follows the container's tag.
        assert_eq!(fleet.decompress(&z).unwrap(), data);
    }
}

#[test]
fn tagged_wire_requests_and_streams_match_direct_and_survive_bad_routes() {
    use llmzip::coordinator::MuxClient;
    let fleet = two_model_fleet(FleetConfig {
        tenants: vec![TenantSpec {
            name: "alice".into(),
            weight: 2,
            rate_bytes_per_sec: 0.0,
            burst_bytes: 0.0,
        }],
        ..Default::default()
    });
    let addr = spawn_listener(fleet);
    let direct_f32 = direct(Precision::F32, Codec::Range, 7);
    let direct_int8 = direct(Precision::Int8, Codec::Fse, 8);
    let a = llmzip::textgen::quick_sample(700, 41);
    let b = llmzip::textgen::quick_sample(500, 42);

    let mut client = MuxClient::connect(&addr).unwrap();
    client.set_tenant("alice").unwrap();
    // Unknown tenants are a clean error, and the connection survives.
    assert!(format!("{:#}", client.set_tenant("mallory").unwrap_err()).contains("mallory"));

    // Tagged one-shots to both models + a tagged stream, interleaved.
    let id_f32 = client.submit_compress_tagged("nano-f32", &a, false).unwrap();
    let id_int8 = client.submit_compress_tagged("nano-int8", &b, true).unwrap();
    let sid = client.open_stream_for("nano-int8").unwrap();
    for piece in a.chunks(173) {
        client.stream_chunk(sid, piece).unwrap();
    }
    client.stream_finish(sid).unwrap();
    // A bad route sheds THIS request only.
    let id_bad = client.submit_compress_tagged("no-such-model", &a, false).unwrap();

    let mut got = std::collections::HashMap::new();
    for _ in 0..4 {
        let (id, result) = client.recv().unwrap();
        got.insert(id, result);
    }
    assert_eq!(got.remove(&id_f32).unwrap().unwrap(), direct_f32.compress(&a).unwrap());
    let z_int8 = got.remove(&id_int8).unwrap().unwrap();
    assert_eq!(z_int8, direct_int8.compress(&b).unwrap());
    assert_eq!(got.remove(&sid).unwrap().unwrap(), direct_int8.compress(&a).unwrap());
    let bad = format!("{:#}", got.remove(&id_bad).unwrap().unwrap_err());
    assert!(bad.contains("no-such-model"), "unexpected error: {bad}");

    // The connection still works: unrouted decompress follows the tag.
    let did = client.submit_decompress(&z_int8).unwrap();
    let (rid, result) = client.recv().unwrap();
    assert_eq!(rid, did);
    assert_eq!(result.unwrap(), b);
}

#[test]
fn page_out_and_back_in_is_byte_identical() {
    let fleet = two_model_fleet(FleetConfig::default());
    let direct_f32 = direct(Precision::F32, Codec::Range, 7);
    let data = llmzip::textgen::quick_sample(900, 51);
    let before = fleet.compress_for(0, "nano-f32", &data).unwrap();

    assert!(fleet.page_out("nano-f32").unwrap());
    assert!(!fleet.is_live("nano-f32").unwrap());
    assert!(!fleet.page_out("nano-f32").unwrap(), "double page-out is a no-op");
    // The other pool is untouched.
    assert!(fleet.is_live("nano-int8").unwrap());

    // Next request re-materializes (fingerprint-checked) and the bytes
    // are EXACTLY the pre-paging and direct-path containers.
    let after = fleet.compress_for(0, "nano-f32", &data).unwrap();
    assert!(fleet.is_live("nano-f32").unwrap());
    assert_eq!(after, before);
    assert_eq!(after, direct_f32.compress(&data).unwrap());
    assert_eq!(fleet.decompress(&after).unwrap(), data);
    assert_eq!(fleet.metrics.page_outs.load(Ordering::Relaxed), 1);
    assert_eq!(fleet.metrics.page_ins.load(Ordering::Relaxed), 1);
}

#[test]
fn memory_budget_pages_out_the_coldest_pool() {
    // A 1-byte budget can hold nothing: at most one pool is ever live
    // (the one a request protects), and switching models churns pages.
    let fleet = two_model_fleet(FleetConfig { memory_budget_bytes: 1, ..Default::default() });
    let data = llmzip::textgen::quick_sample(400, 52);
    let direct_f32 = direct(Precision::F32, Codec::Range, 7);
    let direct_int8 = direct(Precision::Int8, Codec::Fse, 8);
    for round in 0..3 {
        let zf = fleet.compress_for(0, "nano-f32", &data).unwrap();
        assert_eq!(zf, direct_f32.compress(&data).unwrap(), "round {round}");
        let zq = fleet.compress_for(0, "nano-int8", &data).unwrap();
        assert_eq!(zq, direct_int8.compress(&data).unwrap(), "round {round}");
    }
    assert!(
        fleet.metrics.page_outs.load(Ordering::Relaxed) >= 2,
        "budget pressure never paged anything out"
    );
    let live = ["nano-f32", "nano-int8"]
        .iter()
        .filter(|k| fleet.is_live(k).unwrap())
        .count();
    assert!(live <= 1, "1-byte budget left {live} pools live");
}

#[test]
fn changed_weights_on_reload_are_refused() {
    // A loader that returns DIFFERENT weights on each call: the page-in
    // fingerprint check must refuse to serve from the drifted bundle.
    let calls = Arc::new(AtomicUsize::new(0));
    let drifting = {
        let calls = calls.clone();
        FleetModelSpec {
            key: "drifty".into(),
            compressor: compressor_cfg(Precision::F32, Codec::Range),
            server: ServerConfig {
                chunk_tokens: CHUNK,
                policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(2) },
                ..Default::default()
            },
            load: Arc::new(move || {
                let n = calls.fetch_add(1, Ordering::SeqCst) as u64;
                Ok(Weights::random(by_name("nano")?, 100 + n))
            }),
        }
    };
    let fleet = Arc::new(
        FleetServer::start(
            vec![drifting, spec("stable", Precision::F32, Codec::Range, 7)],
            FleetConfig::default(),
        )
        .unwrap(),
    );
    let data = llmzip::textgen::quick_sample(300, 61);
    fleet.compress_for(0, "drifty", &data).unwrap();
    assert!(fleet.page_out("drifty").unwrap());
    let err = format!("{:#}", fleet.compress_for(0, "drifty", &data).unwrap_err());
    assert!(err.contains("changed while paged out"), "unexpected error: {err}");
    assert!(err.contains("fingerprint"), "unexpected error: {err}");
    assert_eq!(calls.load(Ordering::SeqCst), 2, "exactly one reload was attempted");
    // The drifted pool stays out; the rest of the fleet serves on.
    assert!(!fleet.is_live("drifty").unwrap());
    let z = fleet.compress_for(0, "stable", &data).unwrap();
    assert_eq!(fleet.decompress(&z).unwrap(), data);
}

#[test]
fn load_shed_is_a_clean_error_in_process() {
    let fleet = two_model_fleet(FleetConfig { max_inflight: 1, ..Default::default() });
    // Deterministic: an open stream HOLDS the only in-flight slot, so the
    // next submission must shed — with a message, not a hang.
    let stream = fleet.open_wire_stream(0, Some("nano-f32")).unwrap();
    let data = llmzip::textgen::quick_sample(200, 71);
    let err = format!("{:#}", fleet.compress_for(0, "nano-f32", &data).unwrap_err());
    assert!(err.contains("load shed"), "unexpected error: {err}");
    assert!(err.contains("cap 1"), "unexpected error: {err}");
    assert_eq!(fleet.metrics.shed.load(Ordering::Relaxed), 1);
    // Finishing the stream frees the slot; service resumes.
    let mut stream = stream;
    stream.write_bytes(&data).unwrap();
    let z = stream.finish().unwrap().wait().unwrap();
    assert_eq!(fleet.decompress(&z).unwrap(), data);
    let z2 = fleet.compress_for(0, "nano-f32", &data).unwrap();
    assert_eq!(z2, z, "stream and one-shot containers must match");
}

#[test]
fn load_shed_on_the_wire_answers_every_request() {
    use llmzip::coordinator::MuxClient;
    let fleet = two_model_fleet(FleetConfig { max_inflight: 1, ..Default::default() });
    let addr = spawn_listener(fleet);
    let mut client = MuxClient::connect(&addr).unwrap();
    let data = llmzip::textgen::quick_sample(300, 72);
    // The stream pins the only slot server-side...
    let sid = client.open_stream_for("nano-f32").unwrap();
    client.stream_chunk(sid, &data).unwrap();
    // ...so this one-shot must come back as a clean MSG_ERR, while the
    // stream (submitted first) still completes. Every id gets an answer.
    let shed_id = client.submit_compress_tagged("nano-f32", &data, false).unwrap();
    let (rid, result) = client.recv().unwrap();
    assert_eq!(rid, shed_id, "the shed response must arrive first");
    let err = format!("{:#}", result.unwrap_err());
    assert!(err.contains("load shed"), "unexpected error: {err}");
    client.stream_finish(sid).unwrap();
    let (rid, result) = client.recv().unwrap();
    assert_eq!(rid, sid);
    let z = result.unwrap();
    // And the connection keeps serving after the shed.
    let did = client.submit_decompress(&z).unwrap();
    let (rid, result) = client.recv().unwrap();
    assert_eq!(rid, did);
    assert_eq!(result.unwrap(), data);
}

#[test]
fn tenant_rate_limit_refuses_oversize_and_sustained_traffic() {
    let fleet = two_model_fleet(FleetConfig {
        tenants: vec![TenantSpec {
            name: "metered".into(),
            weight: 1,
            rate_bytes_per_sec: 50.0,
            burst_bytes: 600.0,
        }],
        ..Default::default()
    });
    let t = fleet.bind_tenant("metered").unwrap();
    let data = llmzip::textgen::quick_sample(500, 81);
    // First request fits the 600-byte bucket.
    let z = fleet.compress_for(t, "nano-f32", &data).unwrap();
    assert_eq!(fleet.decompress(&z).unwrap(), data);
    // The bucket is nearly empty and refills at 50 B/s: an immediate
    // repeat is refused with the tenant named in the error.
    let err = format!("{:#}", fleet.compress_for(t, "nano-f32", &data).unwrap_err());
    assert!(err.contains("rate limit exceeded"), "unexpected error: {err}");
    assert!(err.contains("metered"), "unexpected error: {err}");
    assert!(fleet.metrics.rate_limited.load(Ordering::Relaxed) >= 1);
    // A request larger than the burst can NEVER pass.
    let huge = llmzip::textgen::quick_sample(2000, 82);
    let err = format!("{:#}", fleet.compress_for(t, "nano-f32", &huge).unwrap_err());
    assert!(err.contains("rate limit exceeded"), "unexpected error: {err}");
    // The anonymous tenant is unmetered.
    let z = fleet.compress_for(0, "nano-f32", &data).unwrap();
    assert_eq!(fleet.decompress(&z).unwrap(), data);
}

#[test]
fn global_budget_caps_replicas_across_pools() {
    // Two pools each wanting 2 replicas under a 3-permit budget: the
    // fleet starts with every permit claimed and no pool at zero.
    let fleet = Arc::new(
        FleetServer::start(
            vec![
                FleetModelSpec {
                    server: ServerConfig {
                        chunk_tokens: CHUNK,
                        replicas: 2,
                        min_replicas: 1,
                        max_replicas: 2,
                        policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(2) },
                        ..Default::default()
                    },
                    ..spec("nano-f32", Precision::F32, Codec::Range, 7)
                },
                FleetModelSpec {
                    server: ServerConfig {
                        chunk_tokens: CHUNK,
                        replicas: 2,
                        min_replicas: 1,
                        max_replicas: 2,
                        codec: Codec::Fse,
                        policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(2) },
                        ..Default::default()
                    },
                    ..spec("nano-int8", Precision::Int8, Codec::Fse, 8)
                },
            ],
            FleetConfig { max_total_replicas: 3, ..Default::default() },
        )
        .unwrap(),
    );
    let budget = fleet.budget().expect("budget configured");
    assert_eq!(budget.cap(), 3);
    assert!(budget.used() <= 3, "budget overshot: {}", budget.used());
    assert!(budget.used() >= 2, "each pool must hold at least one permit");
    // Both pools serve, and the bytes are still the direct bytes.
    let data = llmzip::textgen::quick_sample(350, 91);
    let zf = fleet.compress_for(0, "nano-f32", &data).unwrap();
    assert_eq!(zf, direct(Precision::F32, Codec::Range, 7).compress(&data).unwrap());
    let zq = fleet.compress_for(0, "nano-int8", &data).unwrap();
    assert_eq!(zq, direct(Precision::Int8, Codec::Fse, 8).compress(&data).unwrap());
    // Paging a pool out returns its permits to the shared budget.
    let before = budget.used();
    assert!(fleet.page_out("nano-int8").unwrap());
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    while budget.used() >= before {
        assert!(std::time::Instant::now() < deadline, "page-out never returned permits");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn unknown_routes_and_ambiguous_requests_error_clearly() {
    let fleet = two_model_fleet(FleetConfig::default());
    let data = llmzip::textgen::quick_sample(100, 95);
    let err = format!("{:#}", fleet.compress_for(0, "mystery", &data).unwrap_err());
    assert!(err.contains("mystery"), "unexpected error: {err}");
    assert!(err.contains("nano-f32") && err.contains("nano-int8"), "error must list hosts: {err}");
    // An unrouted compress on a multi-model fleet is ambiguous.
    let buf = fleet.wire_pool().take(data.len());
    let err = {
        let mut buf = buf;
        buf.extend_from_slice(&data);
        let res = fleet.submit_wire(
            0,
            None,
            llmzip::coordinator::Op::Compress(buf),
            llmzip::coordinator::Priority::Bulk,
        );
        format!("{:#}", res.unwrap_err())
    };
    assert!(err.contains("ambiguous"), "unexpected error: {err}");
    // Bare model names route only when unique: both pools are "nano".
    let err = format!("{:#}", fleet.compress_for(0, "nano", &data).unwrap_err());
    assert!(err.contains("nano"), "unexpected error: {err}");
}
