//! Hand-rolled property/fuzz tests: every baseline must round-trip every
//! input family at every size, and reject mutated containers rather than
//! return wrong data silently.

use llmzip::compress::registry::all_baselines;
use llmzip::util::Pcg64;

/// Input families chosen to stress different code paths.
fn families(seed: u64) -> Vec<(&'static str, Vec<u8>)> {
    let mut rng = Pcg64::seeded(seed);
    let mut random = vec![0u8; 3000 + rng.gen_index(3000)];
    rng.fill_bytes(&mut random);
    let text = llmzip::textgen::quick_sample(4000 + rng.gen_index(4000), seed);
    let repetitive: Vec<u8> =
        b"0123456789".iter().copied().cycle().take(2000 + rng.gen_index(5000)).collect();
    let sparse: Vec<u8> = (0..4000).map(|i| if i % 97 == 0 { 255 } else { 0 }).collect();
    let ramp: Vec<u8> = (0..3000u32).map(|i| (i % 251) as u8).collect();
    let mut spiky = text.clone();
    for _ in 0..20 {
        let at = rng.gen_index(spiky.len());
        spiky[at] = rng.next_u32() as u8;
    }
    vec![
        ("random", random),
        ("text", text),
        ("repetitive", repetitive),
        ("sparse", sparse),
        ("ramp", ramp),
        ("spiky", spiky),
    ]
}

#[test]
fn all_baselines_roundtrip_all_families() {
    for seed in 0..4 {
        for (family, data) in families(seed) {
            for c in all_baselines() {
                let z = c
                    .compress(&data)
                    .unwrap_or_else(|e| panic!("{} compress {family} s{seed}: {e}", c.name()));
                let back = c
                    .decompress(&z)
                    .unwrap_or_else(|e| panic!("{} decompress {family} s{seed}: {e}", c.name()));
                assert_eq!(back, data, "{} on {family} seed {seed}", c.name());
            }
        }
    }
}

#[test]
fn boundary_sizes_roundtrip() {
    // Sizes around block/window/alphabet boundaries.
    for &n in &[0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 17, 255, 256, 257, 4095, 4096, 4097,
        65_535, 65_536, 65_537]
    {
        let data = llmzip::textgen::quick_sample(n, n as u64);
        for c in all_baselines() {
            let z = c.compress(&data).unwrap();
            assert_eq!(c.decompress(&z).unwrap(), data, "{} n={n}", c.name());
        }
    }
}

#[test]
fn mutated_streams_never_return_wrong_data_silently() {
    // For the structured formats we can check: a mutation either errors or
    // (rarely, e.g. in unused trailing bits) returns the original bytes.
    // What must NEVER happen is Ok(different bytes) for formats carrying a
    // length/CRC... the baselines don't CRC, so we only demand no panic.
    let data = llmzip::textgen::quick_sample(6000, 77);
    let mut rng = Pcg64::seeded(99);
    for c in all_baselines() {
        let z = c.compress(&data).unwrap();
        for _ in 0..30 {
            let mut zm = z.clone();
            let at = rng.gen_index(zm.len());
            zm[at] ^= 1 << rng.gen_index(8);
            // Must not panic; error or any output is acceptable for
            // non-checksummed formats.
            let _ = c.decompress(&zm);
        }
    }
}

#[test]
fn compression_is_deterministic_across_instances() {
    let data = llmzip::textgen::quick_sample(20_000, 5);
    for name in llmzip::compress::all_baseline_names() {
        let a = llmzip::compress::baseline_by_name(name).unwrap().compress(&data).unwrap();
        let b = llmzip::compress::baseline_by_name(name).unwrap().compress(&data).unwrap();
        assert_eq!(a, b, "{name}");
    }
}

#[test]
fn ratios_track_input_entropy() {
    // Every baseline must compress low-entropy input better than
    // high-entropy input.
    let low: Vec<u8> = b"ab".iter().copied().cycle().take(20_000).collect();
    let mut high = vec![0u8; 20_000];
    Pcg64::seeded(1).fill_bytes(&mut high);
    for c in all_baselines() {
        let zl = c.compress(&low).unwrap().len();
        let zh = c.compress(&high).unwrap().len();
        assert!(zl < zh, "{}: low {} !< high {}", c.name(), zl, zh);
    }
}
