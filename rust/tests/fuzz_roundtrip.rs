//! Hand-rolled property/fuzz tests: every baseline must round-trip every
//! input family at every size, and reject mutated containers rather than
//! return wrong data silently. Plus a seeded property suite over the
//! structured parsers — [`llmzip::compress::ContainerTag`], the `.lmz`
//! v1/v2 weight format, and BOTH `.llmz` container layouts (the legacy
//! table-first v1 and the framed+seekable v2) — where arbitrary
//! truncations (including every frame boundary), flipped dtype/flag
//! bytes, corrupt trailers/indexes and random mutations must yield clear
//! errors: never a panic, never a silently mis-parsed archive.

use llmzip::compress::registry::all_baselines;
use llmzip::compress::{Container, ContainerTag};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use llmzip::util::Pcg64;

/// Input families chosen to stress different code paths.
fn families(seed: u64) -> Vec<(&'static str, Vec<u8>)> {
    let mut rng = Pcg64::seeded(seed);
    let mut random = vec![0u8; 3000 + rng.gen_index(3000)];
    rng.fill_bytes(&mut random);
    let text = llmzip::textgen::quick_sample(4000 + rng.gen_index(4000), seed);
    let repetitive: Vec<u8> =
        b"0123456789".iter().copied().cycle().take(2000 + rng.gen_index(5000)).collect();
    let sparse: Vec<u8> = (0..4000).map(|i| if i % 97 == 0 { 255 } else { 0 }).collect();
    let ramp: Vec<u8> = (0..3000u32).map(|i| (i % 251) as u8).collect();
    let mut spiky = text.clone();
    for _ in 0..20 {
        let at = rng.gen_index(spiky.len());
        spiky[at] = rng.next_u32() as u8;
    }
    vec![
        ("random", random),
        ("text", text),
        ("repetitive", repetitive),
        ("sparse", sparse),
        ("ramp", ramp),
        ("spiky", spiky),
    ]
}

#[test]
fn all_baselines_roundtrip_all_families() {
    for seed in 0..4 {
        for (family, data) in families(seed) {
            for c in all_baselines().expect("baseline registry") {
                let z = c
                    .compress(&data)
                    .unwrap_or_else(|e| panic!("{} compress {family} s{seed}: {e}", c.name()));
                let back = c
                    .decompress(&z)
                    .unwrap_or_else(|e| panic!("{} decompress {family} s{seed}: {e}", c.name()));
                assert_eq!(back, data, "{} on {family} seed {seed}", c.name());
            }
        }
    }
}

#[test]
fn boundary_sizes_roundtrip() {
    // Sizes around block/window/alphabet boundaries.
    for &n in &[0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 17, 255, 256, 257, 4095, 4096, 4097,
        65_535, 65_536, 65_537]
    {
        let data = llmzip::textgen::quick_sample(n, n as u64);
        for c in all_baselines().expect("baseline registry") {
            let z = c.compress(&data).unwrap();
            assert_eq!(c.decompress(&z).unwrap(), data, "{} n={n}", c.name());
        }
    }
}

#[test]
fn mutated_streams_never_return_wrong_data_silently() {
    // For the structured formats we can check: a mutation either errors or
    // (rarely, e.g. in unused trailing bits) returns the original bytes.
    // What must NEVER happen is Ok(different bytes) for formats carrying a
    // length/CRC... the baselines don't CRC, so we only demand no panic.
    let data = llmzip::textgen::quick_sample(6000, 77);
    let mut rng = Pcg64::seeded(99);
    for c in all_baselines().expect("baseline registry") {
        let z = c.compress(&data).unwrap();
        for _ in 0..30 {
            let mut zm = z.clone();
            let at = rng.gen_index(zm.len());
            zm[at] ^= 1 << rng.gen_index(8);
            // Must not panic; error or any output is acceptable for
            // non-checksummed formats.
            let _ = c.decompress(&zm);
        }
    }
}

#[test]
fn compression_is_deterministic_across_instances() {
    let data = llmzip::textgen::quick_sample(20_000, 5);
    for name in llmzip::compress::all_baseline_names() {
        let a = llmzip::compress::baseline_by_name(name).unwrap().compress(&data).unwrap();
        let b = llmzip::compress::baseline_by_name(name).unwrap().compress(&data).unwrap();
        assert_eq!(a, b, "{name}");
    }
}

// ---------------------------------------------------------------------
// Structured-format property suite: ContainerTag + .lmz v1/v2.
// ---------------------------------------------------------------------

#[test]
fn container_tag_parse_total_over_arbitrary_strings() {
    // Valid tags roundtrip their fields; everything else errors. Nothing
    // panics, whatever the string.
    let valid = [
        ("nano:0", "nano", false),
        ("medium:2", "medium", false),
        ("small:0:q8:00c0ffee", "small", true),
        ("large:1:q8:ffffffff", "large", true),
        ("nano:0:fse", "nano", false),
        ("large:1:q8:ffffffff:fse", "large", true),
    ];
    for (tag, model, quant) in valid {
        let t = ContainerTag::parse(tag).unwrap_or_else(|e| panic!("{tag}: {e}"));
        assert_eq!(t.model, model);
        assert_eq!(t.fingerprint.is_some(), quant, "{tag}");
    }
    // Structured near-misses: every one must be a clean error.
    for bad in [
        "", "untagged", "nano", "nano:", "nano:x", "nano:65536", "nano:99",
        "nano:0:q8", "nano:0:q8:", "nano:0:q8:zzzz", "nano:0:q8:00c0ffee:extra",
        "nano:0:fp16:00c0ffee", "nano:0:q16:00c0ffee", "nano:0:q8:123456789abcdef0",
        "::::", "a:b:c:d", "nano:0:tans", "nano:0:fse:extra", "nano:0:fse:00c0ffee",
        "nano:0:q8:00c0ffee:fse:extra", "nano:0:FSE",
    ] {
        assert!(ContainerTag::parse(bad).is_err(), "'{bad}' must not parse");
    }
    // Seeded arbitrary ASCII soup: Ok or Err, never panic; anything Ok
    // must have parsed a real executor flag.
    let mut rng = Pcg64::seeded(271828);
    let alphabet: Vec<char> = ":0123456789abcdefq8sxyz ".chars().collect();
    for _ in 0..2000 {
        let len = rng.gen_index(24);
        let s: String = (0..len).map(|_| alphabet[rng.gen_index(alphabet.len())]).collect();
        if let Ok(t) = ContainerTag::parse(&s) {
            assert!(matches!(t.executor.as_flag(), 0 | 1 | 2), "'{s}'");
        }
    }
}

#[test]
fn lmz_truncations_always_error_never_panic() {
    // EVERY proper prefix of a valid .lmz file (both versions) must be
    // rejected; the full file must load and re-serialize byte-exactly.
    let cfg = by_name("nano").unwrap();
    for (name, bytes) in [
        ("v1", Weights::random(cfg, 11).to_bytes()),
        ("v2", Weights::random(cfg, 11).quantize().to_bytes()),
    ] {
        let w = Weights::from_bytes(&bytes, cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(w.to_bytes(), bytes, "{name} roundtrip");
        // Exhaustive over the header + structure region, sampled over the
        // (large, homogeneous) payload tail.
        let mut cuts: Vec<usize> = (0..200.min(bytes.len())).collect();
        let mut rng = Pcg64::seeded(31337);
        for _ in 0..300 {
            cuts.push(rng.gen_index(bytes.len()));
        }
        for cut in cuts {
            assert!(
                Weights::from_bytes(&bytes[..cut], cfg).is_err(),
                "{name} prefix of {cut} bytes must not parse"
            );
        }
    }
}

#[test]
fn lmz_flipped_dtype_bytes_and_corrupt_scale_tables_error_clearly() {
    let cfg = by_name("nano").unwrap();
    let v2 = Weights::random(cfg, 12).quantize().to_bytes();
    // Locate the first tensor's header: 8-byte file header, then
    // `len("embed")` prefix + name + ndim byte + 2 dims (embed is 2-D).
    let name_len = v2[8] as usize;
    assert_eq!(&v2[9..9 + name_len], b"embed");
    let dt = 8 + 1 + name_len + 1 + 2 * 4;
    assert_eq!(v2[dt], 1, "embed is int8 in a quantized bundle");
    // Unknown dtype byte: clear error naming the dtype.
    let mut bad = v2.clone();
    bad[dt] = 7;
    let err = Weights::from_bytes(&bad, cfg).unwrap_err().to_string();
    assert!(err.contains("dtype"), "{err}");
    // Dtype flipped i8 -> f32: the parser now walks a differently-sized
    // payload and must desync into a structural error, not mis-load.
    let mut flipped = v2.clone();
    flipped[dt] = 0;
    assert!(Weights::from_bytes(&flipped, cfg).is_err());
    // Corrupt scale-table length: a huge count must be a clean truncation
    // error (never an OOM attempt or a panic).
    let mut huge = v2.clone();
    huge[dt + 1..dt + 5].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = Weights::from_bytes(&huge, cfg).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");
    // A v1 file whose dtype region doesn't exist: flipping its version
    // byte to v2 shifts parsing into payload bytes -> error, no panic.
    let v1 = Weights::random(cfg, 12).to_bytes();
    let mut misversioned = v1.clone();
    misversioned[4] = 2;
    let _ = Weights::from_bytes(&misversioned, cfg);
    // Unsupported future version is refused by name.
    let mut v9 = v1.clone();
    v9[4] = 9;
    let err = Weights::from_bytes(&v9, cfg).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");
}

#[test]
fn lmz_random_mutations_never_panic_and_ok_parses_stay_spec_valid() {
    // Seeded byte flips anywhere in the file: the loader must never panic,
    // and any mutation it ACCEPTS must still have produced a bundle that
    // matches the model's parameter spec exactly (same names, same shapes,
    // byte-exact re-serialization of whatever was parsed) — corrupt data
    // may change values, never structure.
    let cfg = by_name("nano").unwrap();
    for (seed, bytes) in [
        (21u64, Weights::random(cfg, 13).to_bytes()),
        (22u64, Weights::random(cfg, 13).quantize().to_bytes()),
    ] {
        let mut rng = Pcg64::seeded(seed);
        for _ in 0..400 {
            let mut m = bytes.clone();
            for _ in 0..1 + rng.gen_index(3) {
                let at = rng.gen_index(m.len());
                m[at] ^= 1 << rng.gen_index(8);
            }
            if let Ok(w) = Weights::from_bytes(&m, cfg) {
                assert_eq!(w.tensors.len(), llmzip::lm::config::param_spec(cfg).len());
                for ((name, shape), t) in
                    llmzip::lm::config::param_spec(cfg).iter().zip(&w.tensors)
                {
                    assert_eq!(&t.name, name);
                    assert_eq!(&t.shape, shape);
                }
                // Whatever parsed must re-serialize to what was parsed
                // from (same length ⇒ same framing): no silent resync.
                assert_eq!(w.to_bytes().len(), m.len());
            }
        }
    }
}

#[test]
fn lmz_v1_v2_to_bytes_from_bytes_roundtrip_property() {
    // Property over seeds and models: serialize -> parse -> serialize is
    // the identity for both the f32 (v1) and quantized (v2) formats, and
    // quantization commutes with a save/load cycle.
    for model in ["nano", "tiny"] {
        let cfg = by_name(model).unwrap();
        for seed in 0..3u64 {
            let w = Weights::random(cfg, seed);
            let b1 = w.to_bytes();
            let r1 = Weights::from_bytes(&b1, cfg).unwrap();
            assert_eq!(r1.to_bytes(), b1, "{model} s{seed} v1");
            let q = w.quantize();
            let b2 = q.to_bytes();
            let r2 = Weights::from_bytes(&b2, cfg).unwrap();
            assert_eq!(r2.to_bytes(), b2, "{model} s{seed} v2");
            assert_eq!(
                r1.quantize().to_bytes(),
                b2,
                "{model} s{seed}: quantize must commute with save/load"
            );
            assert_eq!(q.fingerprint(), r2.fingerprint(), "{model} s{seed}");
        }
    }
}

/// The shared container fixture for the format property tests.
fn fixture_container() -> Container {
    Container::v1(
        10,
        0x1234_5678,
        64,
        "nano:0".into(),
        vec![
            llmzip::compress::ChunkRecord { comp_len: 4, n_tokens: 6 },
            llmzip::compress::ChunkRecord { comp_len: 3, n_tokens: 4 },
        ],
        vec![9, 8, 7, 6, 5, 4, 3],
    )
}

#[test]
fn container_truncations_and_chunk_table_lies_always_error() {
    // The outer .llmz container gets the same treatment: every prefix
    // errors, and a chunk table that disagrees with the payload (or the
    // recorded length) is refused structurally.
    let c = fixture_container();
    let bytes = c.to_bytes();
    assert_eq!(Container::from_bytes(&bytes).unwrap().payload, c.payload);
    for cut in 0..bytes.len() {
        assert!(Container::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
    }
    // Payload shorter than the table claims.
    let mut short = c.clone();
    short.payload.pop();
    assert!(Container::from_bytes(&short.to_bytes()).is_err());
    // Token sum disagreeing with orig_len.
    let mut lying = c.clone();
    lying.chunks[0].n_tokens = 99;
    assert!(Container::from_bytes(&lying.to_bytes()).is_err());
    // Seeded random flips: never panic; Ok parses keep the framing.
    let mut rng = Pcg64::seeded(55);
    for _ in 0..500 {
        let mut m = bytes.clone();
        let at = rng.gen_index(m.len());
        m[at] ^= 1 << rng.gen_index(8);
        if let Ok(parsed) = Container::from_bytes(&m) {
            assert_eq!(parsed.to_bytes().len(), m.len());
        }
    }
}

#[test]
fn container_v2_truncations_and_frame_corruptions_always_error() {
    // The framed v2 layout: EVERY proper prefix errors (that covers
    // truncation at every frame boundary, mid-frame, mid-index and mid-
    // trailer), a frame header that disagrees with the trailer index is
    // refused by name, and random mutations never panic — an accepted
    // mutation must re-serialize to the same framing.
    let mut c = fixture_container();
    c.version = llmzip::compress::CONTAINER_V2;
    c.flags = llmzip::compress::container::FLAG_SEEKABLE;
    let bytes = c.to_bytes();
    let parsed = Container::from_bytes(&bytes).unwrap();
    assert_eq!(parsed.payload, c.payload);
    assert_eq!(parsed.to_bytes(), bytes, "v2 parse -> re-encode is the identity");
    for cut in 0..bytes.len() {
        assert!(Container::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
    }
    // Trailing garbage is structural corruption, not slack.
    let mut noisy = bytes.clone();
    noisy.extend_from_slice(&[0, 0, 0]);
    assert!(Container::from_bytes(&noisy).is_err());
    // Every single-byte flip anywhere in the container: never a panic,
    // and an Ok parse must preserve the framing exactly. (The v2 fixture
    // is small enough to sweep exhaustively over all bit positions.)
    for at in 0..bytes.len() {
        for bit in 0..8 {
            let mut m = bytes.clone();
            m[at] ^= 1 << bit;
            if let Ok(parsed) = Container::from_bytes(&m) {
                assert_eq!(parsed.to_bytes().len(), m.len(), "at={at} bit={bit}");
            }
        }
    }
}

#[test]
fn container_flag_bits_round_trip_and_unknown_bits_are_refused() {
    // Satellite regression: `to_bytes` used to hardcode flags to 0 and
    // `from_bytes` never looked. Now the field round-trips, and any bit
    // this release does not define is a refusal — the forward-compat
    // guard that made the v2 introduction safe.
    let v1 = fixture_container().to_bytes();
    let mut v2 = fixture_container();
    v2.version = llmzip::compress::CONTAINER_V2;
    v2.flags = llmzip::compress::container::FLAG_SEEKABLE;
    let v2 = v2.to_bytes();
    assert_eq!(Container::from_bytes(&v1).unwrap().flags, 0);
    assert_eq!(
        Container::from_bytes(&v2).unwrap().flags,
        llmzip::compress::container::FLAG_SEEKABLE
    );
    // Flags live at byte offset 6 in both layouts.
    for unknown in [0x0001u16, 0x0002, 0x8000, 0xFFFF] {
        let mut m = v1.clone();
        m[6..8].copy_from_slice(&unknown.to_le_bytes());
        let err = Container::from_bytes(&m).unwrap_err().to_string();
        assert!(err.contains("flag"), "v1 {unknown:#06x}: {err}");
    }
    // 0x0002 (fse) became a KNOWN v2 bit in this release: seekable|fse
    // parses and the field round-trips...
    let mut fse_flags = v2.clone();
    fse_flags[6..8].copy_from_slice(&0x0003u16.to_le_bytes());
    assert_eq!(Container::from_bytes(&fse_flags).unwrap().flags, 0x0003);
    // ...while any bit BEYOND the validated set is still refused by name —
    // the guarantee that pre-fse decoders refuse fse containers cleanly.
    for unknown in [0x0005u16, 0x8001, 0xFFFC, 0xFFFF] {
        let mut m = v2.clone();
        m[6..8].copy_from_slice(&unknown.to_le_bytes());
        let err = Container::from_bytes(&m).unwrap_err().to_string();
        assert!(err.contains("flag"), "v2 {unknown:#06x}: {err}");
    }
    // An unknown future VERSION is refused by name too.
    let mut m = v1.clone();
    m[4] = 9;
    let err = Container::from_bytes(&m).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");
}

#[test]
fn container_v1_fixture_bytes_still_parse() {
    // A byte-for-byte v1 fixture (the exact layout every pre-v2 release
    // wrote, assembled by hand so no current code path can contaminate
    // it) must keep parsing and re-encode to itself.
    let mut fixture: Vec<u8> = Vec::new();
    fixture.extend_from_slice(&0x3150_5A4Cu32.to_le_bytes()); // "LZP1"
    fixture.extend_from_slice(&1u16.to_le_bytes()); // version
    fixture.extend_from_slice(&0u16.to_le_bytes()); // flags
    fixture.extend_from_slice(&5u64.to_le_bytes()); // orig_len
    fixture.extend_from_slice(&0xAABB_CCDDu32.to_le_bytes()); // crc
    fixture.extend_from_slice(&64u32.to_le_bytes()); // chunk_tokens
    fixture.push(6); // name len
    fixture.extend_from_slice(b"nano:0");
    fixture.extend_from_slice(&1u32.to_le_bytes()); // n_chunks
    fixture.extend_from_slice(&3u32.to_le_bytes()); // comp_len
    fixture.extend_from_slice(&5u32.to_le_bytes()); // n_tokens
    fixture.extend_from_slice(&[0xDE, 0xAD, 0xBF]); // payload
    let c = Container::from_bytes(&fixture).unwrap();
    assert_eq!(c.version, llmzip::compress::CONTAINER_V1);
    assert_eq!(c.orig_len, 5);
    assert_eq!(c.model_name, "nano:0");
    assert_eq!(c.payload, vec![0xDE, 0xAD, 0xBF]);
    assert_eq!(c.to_bytes(), fixture, "v1 fixture re-encodes byte-exactly");
}

// ---------------------------------------------------------------------
// Rank-frame (fse codec) property suite: the per-stream tANS frames the
// fse backend writes into v2 containers.
// ---------------------------------------------------------------------

/// A model-shaped rank stream: heavily skewed toward rank 0 with a thin
/// escape tail, the distribution the fse path is built for.
fn skewed_ranks(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| {
            let r = rng.gen_index(1000);
            if r < 880 {
                0
            } else if r < 995 {
                1 + rng.gen_index(8) as u8
            } else {
                64 + rng.gen_index(192) as u8 // escape literals
            }
        })
        .collect()
}

#[test]
fn rank_frames_roundtrip_and_reject_every_prefix() {
    use llmzip::compress::rank::{decode_rank_stream, encode_rank_stream};
    for (name, ranks) in [
        ("skewed", skewed_ranks(3000, 41)),
        ("all-zero", vec![0u8; 500]),
        ("all-escape", vec![200u8; 64]),
        ("every-rank", (0u8..=255).collect()),
        ("single", vec![3u8]),
        ("empty", vec![]),
    ] {
        let frame = encode_rank_stream(&ranks).unwrap();
        assert_eq!(decode_rank_stream(&frame, ranks.len()).unwrap(), ranks, "{name}");
        for cut in 0..frame.len() {
            assert!(
                decode_rank_stream(&frame[..cut], ranks.len()).is_err(),
                "{name}: prefix of {cut} bytes must not decode"
            );
        }
    }
}

#[test]
fn rank_frame_arbitrary_bytes_and_bit_flips_never_panic() {
    use llmzip::compress::rank::{decode_rank_stream, encode_rank_stream};
    // Pure junk: Ok or Err, never a panic; an Ok decode must have the
    // requested length (wrong VALUES are the container CRC's job).
    let mut rng = Pcg64::seeded(4242);
    for _ in 0..500 {
        let mut junk = vec![0u8; rng.gen_index(80)];
        rng.fill_bytes(&mut junk);
        let n = rng.gen_index(256);
        if let Ok(out) = decode_rank_stream(&junk, n) {
            assert_eq!(out.len(), n);
        }
    }
    // Every single-bit flip of a real frame: same contract.
    let ranks = skewed_ranks(400, 43);
    let frame = encode_rank_stream(&ranks).unwrap();
    for at in 0..frame.len() {
        for bit in 0..8 {
            let mut m = frame.clone();
            m[at] ^= 1 << bit;
            if let Ok(out) = decode_rank_stream(&m, ranks.len()) {
                assert_eq!(out.len(), ranks.len(), "at={at} bit={bit}");
            }
        }
    }
}

#[test]
fn fse_histogram_and_table_roundtrip_property() {
    use llmzip::entropy::fse::{
        decode_all, encode_all, normalize_freqs, pack_norm, unpack_norm, FseTable,
    };
    let mut rng = Pcg64::seeded(271);
    for trial in 0..40 {
        let alphabet = 1 + rng.gen_index(65);
        let table_log = 6 + (trial % 5) as u32; // 6..=10
        // Random counts with at least one present symbol.
        let mut counts = vec![0u64; alphabet];
        for c in counts.iter_mut() {
            *c = rng.gen_index(1000) as u64;
        }
        counts[rng.gen_index(alphabet)] += 1;
        let Ok(norm) = normalize_freqs(&counts, table_log) else {
            continue; // tiny tables can legitimately refuse wide alphabets
        };
        // Histogram serialization round-trips exactly.
        let packed = pack_norm(&norm);
        assert_eq!(unpack_norm(&packed, norm.len(), table_log).unwrap(), norm, "t{trial}");
        // And the table built from it codes a random stream losslessly.
        let table = FseTable::new(&norm, table_log).unwrap();
        let present: Vec<usize> =
            (0..alphabet).filter(|&s| norm[s] > 0).collect();
        let symbols: Vec<usize> =
            (0..2000).map(|_| present[rng.gen_index(present.len())]).collect();
        let (state, payload) = encode_all(&table, &symbols);
        let back = decode_all(&table, state, &payload, symbols.len()).unwrap();
        assert_eq!(back, symbols, "t{trial} log={table_log} n={alphabet}");
    }
}

#[test]
fn ratios_track_input_entropy() {
    // Every baseline must compress low-entropy input better than
    // high-entropy input.
    let low: Vec<u8> = b"ab".iter().copied().cycle().take(20_000).collect();
    let mut high = vec![0u8; 20_000];
    Pcg64::seeded(1).fill_bytes(&mut high);
    for c in all_baselines().expect("baseline registry") {
        let zl = c.compress(&low).unwrap().len();
        let zh = c.compress(&high).unwrap().len();
        assert!(zl < zh, "{}: low {} !< high {}", c.name(), zl, zh);
    }
}
