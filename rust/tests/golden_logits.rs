//! Golden bit-exactness regression for the resolved-plan/batched engine.
//!
//! The hard constraint of the engine refactor: containers compressed by
//! the pre-refactor (seed) code MUST still decompress, which requires the
//! refactored `advance_batch` to reproduce the seed `advance` **bit for
//! bit**. The seed implementation is frozen verbatim in
//! `llmzip::lm::reference` (deterministic weights, fixed token sequences),
//! so these tests ARE the golden fixtures — regenerated from the exact
//! seed arithmetic on every run instead of baked into a binary blob, and
//! covering every model tier instead of one.

use llmzip::compress::llm::{logits_to_cdf, CDF_TOTAL};
use llmzip::compress::{ChunkRecord, Compressor, Container, LlmCompressor};
use llmzip::entropy::range::RangeEncoder;
use llmzip::lm::config::{by_name, CODED_BYTES, MAX_CONTEXT, VOCAB};
use llmzip::lm::executor::LmExecutor;
use llmzip::lm::native::{LaneState, NativeExecutor, NativeModel, Scratch};
use llmzip::lm::reference::{ReferenceLane, ReferenceModel};
use llmzip::lm::weights::Weights;
use llmzip::tokenizer::vocab::BOS;
use llmzip::util::crc32;

/// Deterministic pseudo-text for lane `l`: BOS then bytes.
fn golden_tokens(lane: usize, len: usize) -> Vec<u32> {
    let mut toks = vec![BOS];
    toks.extend((0..len - 1).map(|i| ((i * 37 + lane * 101 + 11) % 256) as u32));
    toks
}

#[test]
fn advance_batch_matches_seed_reference_bit_for_bit() {
    // Every tier that differs structurally (layers/heads/width), three
    // lanes, 24 steps — compared against the frozen seed implementation
    // with exact f32 equality.
    for (name, seed) in [("nano", 1u64), ("tiny", 2), ("small", 3), ("medium", 4), ("large", 5)] {
        let cfg = by_name(name).unwrap();
        let weights = Weights::random(cfg, seed);
        let reference = ReferenceModel::new(cfg, weights.clone());
        let model = NativeModel::new(cfg, weights);

        let n_lanes = 3;
        let steps = 24;
        let seqs: Vec<Vec<u32>> = (0..n_lanes).map(|l| golden_tokens(l, steps)).collect();

        let mut ref_lanes: Vec<ReferenceLane> =
            (0..n_lanes).map(|_| ReferenceLane::new(cfg, steps)).collect();
        let mut lanes: Vec<LaneState> = (0..n_lanes).map(|_| LaneState::new(cfg, steps)).collect();
        let mut scratch = Scratch::new(cfg, n_lanes);
        let mut out = vec![0.0f32; n_lanes * VOCAB];

        for t in 0..steps {
            let toks: Vec<u32> = seqs.iter().map(|s| s[t]).collect();
            model.advance_batch(&mut lanes, &toks, &mut scratch, &mut out, VOCAB).unwrap();
            for (l, rl) in ref_lanes.iter_mut().enumerate() {
                let expected = reference.advance(rl, toks[l]).unwrap();
                let got = &out[l * VOCAB..(l + 1) * VOCAB];
                assert_eq!(
                    got,
                    &expected[..],
                    "{name}: logits diverged from seed at step {t}, lane {l}"
                );
            }
        }
    }
}

#[test]
fn coded_head_matches_seed_cdf_exactly() {
    // The compressor's native engine computes only the 256 coded logit
    // rows; the quantized CDF must equal the seed's (full-head) CDF at
    // every position — this is what keeps streams cross-decodable.
    let cfg = by_name("small").unwrap();
    let weights = Weights::random(cfg, 6);
    let reference = ReferenceModel::new(cfg, weights.clone());
    let mut coded = NativeExecutor::new(cfg, weights, 1).with_head_rows(CODED_BYTES);

    let toks = golden_tokens(0, 20);
    let mut rl = ReferenceLane::new(cfg, MAX_CONTEXT);
    for &t in &toks {
        let expected = reference.advance(&mut rl, t).unwrap();
        let got = coded.step(&[t]).unwrap();
        assert_eq!(got[..CODED_BYTES], expected[..CODED_BYTES], "coded logit rows");
        assert_eq!(logits_to_cdf(&got), logits_to_cdf(&expected), "quantized CDF");
    }
}

/// Replicate the SEED compression pipeline (reference model + stepping
/// encode, exactly what `Engine::encode_logits`'s fallback did in the
/// pre-refactor `compress/llm.rs`) and build a seed-format container.
fn seed_compress(cfg_name: &str, weights_seed: u64, chunk_tokens: usize, data: &[u8]) -> Vec<u8> {
    let cfg = by_name(cfg_name).unwrap();
    let reference = ReferenceModel::new(cfg, Weights::random(cfg, weights_seed));
    let stream_bytes = 4 * chunk_tokens; // from_weights' stream granularity
    let mut records = Vec::new();
    let mut payload = Vec::new();
    for stream in data.chunks(stream_bytes) {
        let mut enc = RangeEncoder::new();
        for win in stream.chunks(chunk_tokens) {
            // Lane input: BOS + window bytes except the last.
            let mut lane_toks = vec![BOS];
            lane_toks.extend(win[..win.len() - 1].iter().map(|&b| b as u32));
            let mut lane = ReferenceLane::new(cfg, MAX_CONTEXT);
            for (t, &byte) in win.iter().enumerate() {
                let logits = reference.advance(&mut lane, lane_toks[t]).unwrap();
                let cdf = logits_to_cdf(&logits);
                let s = byte as usize;
                enc.encode(cdf[s], cdf[s + 1] - cdf[s], CDF_TOTAL);
            }
        }
        let comp = enc.finish();
        records.push(ChunkRecord { comp_len: comp.len() as u32, n_tokens: stream.len() as u32 });
        payload.extend(comp);
    }
    // The seed code serialized the table-first layout — container v1.
    Container::v1(
        data.len() as u64,
        crc32(data),
        chunk_tokens as u32,
        format!("{cfg_name}:0"), // ExecutorKind::Native flag
        records,
        payload,
    )
    .to_bytes()
}

#[test]
fn pre_refactor_container_decompresses_with_refactored_engine() {
    let data = llmzip::textgen::quick_sample(300, 42);
    let container = seed_compress("nano", 7, 32, &data);

    let cfg = by_name("nano").unwrap();
    let modern = LlmCompressor::from_weights(cfg, Weights::random(cfg, 7), 32, 2).unwrap();
    let back = modern.decompress(&container).unwrap();
    assert_eq!(back, data, "seed-era container must decode bit-exactly");

    // The modern encoder now emits the framed v2 envelope, but the
    // BITSTREAM — every record and every range-coded payload byte — must
    // still be exactly the seed's. Re-enveloping the modern container as
    // v1 must reproduce the seed container byte-for-byte (the envelope is
    // the only thing that moved), and the parsed seed container must
    // round-trip byte-exactly through `to_bytes`.
    let z = modern.compress(&data).unwrap();
    let mut parsed = Container::from_bytes(&z).unwrap();
    assert_eq!(parsed.version, llmzip::compress::CONTAINER_V2);
    parsed.version = llmzip::compress::CONTAINER_V1;
    parsed.flags = 0;
    assert_eq!(
        parsed.to_bytes(),
        container,
        "modern encoder must emit the seed bitstream (v2 envelope aside)"
    );
    let seed_parsed = Container::from_bytes(&container).unwrap();
    assert_eq!(seed_parsed.to_bytes(), container, "v1 re-encodes byte-exactly");
}
