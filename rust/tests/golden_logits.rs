//! Golden bit-exactness regression for the kernel-dispatch engine.
//!
//! PR 6 moved the engine's f32 reductions from the seed's ascending-order
//! scalar loops to ONE fixed tree order shared by every dispatch tier
//! (see `lm/kernels`). The golden contract moves with it:
//!
//! * The pinned expectation is an **independent in-test re-derivation** of
//!   the transformer (`tree_ref` below) that spells out the fixed-tree
//!   dot with plain loops — no calls into `lm::kernels` — so a bug in the
//!   kernel layer cannot hide by being on both sides of the assertion.
//!   `advance_batch` must reproduce it bit for bit on every model tier,
//!   for every available kernel tier, with panels on and off.
//! * The frozen seed implementation (`lm::reference`) is now a *drift
//!   bound*, not a bit-for-bit target: the fixed-tree logits must stay
//!   numerically close to the seed's (same math, different summation
//!   order), and the container test below documents that the BITSTREAM
//!   legitimately changed — pre-PR6 containers no longer decode, both
//!   ends of a stream move together.

use llmzip::compress::llm::{logits_to_cdf, CDF_TOTAL};
use llmzip::compress::{ChunkRecord, Compressor, Container, LlmCompressor};
use llmzip::entropy::range::RangeEncoder;
use llmzip::lm::config::{by_name, LmConfig, CODED_BYTES, MAX_CONTEXT, VOCAB};
use llmzip::lm::executor::LmExecutor;
use llmzip::lm::native::{LaneState, NativeExecutor, NativeModel, Scratch};
use llmzip::lm::reference::{ReferenceLane, ReferenceModel};
use llmzip::lm::weights::Weights;
use llmzip::lm::{KernelOptions, KernelTier};
use llmzip::tokenizer::vocab::BOS;
use llmzip::util::crc32;

/// Deterministic pseudo-text for lane `l`: BOS then bytes.
fn golden_tokens(lane: usize, len: usize) -> Vec<u32> {
    let mut toks = vec![BOS];
    toks.extend((0..len - 1).map(|i| ((i * 37 + lane * 101 + 11) % 256) as u32));
    toks
}

/// Kernel variants to pin: the scalar specification plus the best tier
/// this CPU supports (when it differs), each with panels on and off.
fn kernel_variants() -> Vec<KernelOptions> {
    let mut tiers = vec![KernelTier::Scalar];
    let best = KernelTier::detect();
    if best != KernelTier::Scalar {
        tiers.push(best);
    }
    let mut out = Vec::new();
    for tier in tiers {
        for panels in [true, false] {
            out.push(KernelOptions { tier: Some(tier), panels });
        }
    }
    out
}

/// The independent fixed-tree re-derivation of the transformer. Same
/// structure as the frozen seed (`lm::reference`), with every dot product
/// rewritten in the canonical tree order the kernel layer promises:
/// element `i` accumulates into lane `i % 8`, lanes combine as
/// `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`. Deliberately written with
/// bare loops and string-keyed weight lookups — it shares no code with
/// the engine under test.
mod tree_ref {
    use super::*;

    const LANES: usize = 8;

    fn combine8(l: &[f32; LANES]) -> f32 {
        let s0 = l[0] + l[4];
        let s1 = l[1] + l[5];
        let s2 = l[2] + l[6];
        let s3 = l[3] + l[7];
        (s0 + s2) + (s1 + s3)
    }

    fn tree_dot(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        let mut lanes = [0.0f32; LANES];
        for i in 0..a.len() {
            lanes[i % LANES] += a[i] * b[i];
        }
        combine8(&lanes)
    }

    /// Fixed-tree dot of `x` against column `col` of a row-major
    /// `[d_in, d_out]` matrix.
    fn tree_dot_col(x: &[f32], w: &[f32], col: usize, d_out: usize) -> f32 {
        let mut lanes = [0.0f32; LANES];
        for (i, &xi) in x.iter().enumerate() {
            lanes[i % LANES] += xi * w[i * d_out + col];
        }
        combine8(&lanes)
    }

    fn tree_matvec(x: &[f32], w: &[f32], d_out: usize) -> Vec<f32> {
        (0..d_out).map(|j| tree_dot_col(x, w, j, d_out)).collect()
    }

    fn tree_matvec_acc(x: &[f32], w: &[f32], y: &mut [f32]) {
        let d_out = y.len();
        assert_eq!(x.len() * d_out, w.len());
        for (j, yj) in y.iter_mut().enumerate() {
            *yj += tree_dot_col(x, w, j, d_out);
        }
    }

    /// Same constant and expression as the seed and the engine.
    fn gelu(x: f32) -> f32 {
        const C: f32 = 0.797_884_6; // sqrt(2/pi)
        0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
    }

    /// Ascending-order mean square, exactly like seed and engine (the
    /// fixed tree applies to weight dots only — norms were never
    /// reordered).
    fn rmsnorm(x: &[f32], gain: &[f32]) -> Vec<f32> {
        let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        x.iter().zip(gain).map(|(v, g)| v * inv * g).collect()
    }

    pub struct Lane {
        /// [layer][kind(k=0,v=1)][pos * d_model ..]
        kv: Vec<f32>,
        pos: usize,
        d_model: usize,
        max_len: usize,
    }

    impl Lane {
        pub fn new(cfg: &LmConfig, max_len: usize) -> Lane {
            Lane {
                kv: vec![0.0; cfg.n_layers * 2 * max_len * cfg.d_model],
                pos: 0,
                d_model: cfg.d_model,
                max_len,
            }
        }

        fn kv_slice(&self, layer: usize, kind: usize, pos: usize) -> std::ops::Range<usize> {
            let base = ((layer * 2 + kind) * self.max_len + pos) * self.d_model;
            base..base + self.d_model
        }
    }

    pub struct Model {
        cfg: &'static LmConfig,
        weights: Weights,
        slopes: Vec<f32>,
    }

    impl Model {
        pub fn new(cfg: &'static LmConfig, weights: Weights) -> Model {
            let slopes = (0..cfg.n_heads).map(|h| cfg.alibi_slope(h)).collect();
            Model { cfg, weights, slopes }
        }

        pub fn advance(&self, st: &mut Lane, token: u32) -> Vec<f32> {
            assert!(st.pos < st.max_len, "tree_ref lane overflow");
            let d = self.cfg.d_model;
            let h = self.cfg.n_heads;
            let dh = self.cfg.d_head();
            let pos = st.pos;
            let embed: &[f32] = &self.weights.get("embed").data;
            let mut x: Vec<f32> = embed[token as usize * d..(token as usize + 1) * d].to_vec();

            for layer in 0..self.cfg.n_layers {
                let p = format!("layer{layer:02}.");
                let hn = rmsnorm(&x, &self.weights.get(&format!("{p}attn_norm")).data);
                let q = tree_matvec(&hn, &self.weights.get(&format!("{p}wq")).data, d);
                let k = tree_matvec(&hn, &self.weights.get(&format!("{p}wk")).data, d);
                let v = tree_matvec(&hn, &self.weights.get(&format!("{p}wv")).data, d);
                let kr = st.kv_slice(layer, 0, pos);
                st.kv[kr].copy_from_slice(&k);
                let vr = st.kv_slice(layer, 1, pos);
                st.kv[vr].copy_from_slice(&v);

                let scale = 1.0 / (dh as f32).sqrt();
                let mut attn_out = vec![0.0f32; d];
                for head in 0..h {
                    let slope = self.slopes[head];
                    let qh = &q[head * dh..(head + 1) * dh];
                    let mut scores = Vec::with_capacity(pos + 1);
                    let mut max_s = f32::NEG_INFINITY;
                    for j in 0..=pos {
                        let kj = &st.kv[st.kv_slice(layer, 0, j)][head * dh..(head + 1) * dh];
                        let s = tree_dot(qh, kj) * scale - slope * (pos - j) as f32;
                        max_s = max_s.max(s);
                        scores.push(s);
                    }
                    let mut denom = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - max_s).exp();
                        denom += *s;
                    }
                    let inv = 1.0 / denom;
                    let out = &mut attn_out[head * dh..(head + 1) * dh];
                    for (j, &w) in scores.iter().enumerate() {
                        let vj = &st.kv[st.kv_slice(layer, 1, j)][head * dh..(head + 1) * dh];
                        let wj = w * inv;
                        // Value accumulation is element-wise (the engine's
                        // axpy): per-element order is j-ascending on both
                        // sides, no reduction to reorder.
                        for i in 0..dh {
                            out[i] += wj * vj[i];
                        }
                    }
                }
                tree_matvec_acc(&attn_out, &self.weights.get(&format!("{p}wo")).data, &mut x);

                let hn = rmsnorm(&x, &self.weights.get(&format!("{p}mlp_norm")).data);
                let mut ff =
                    tree_matvec(&hn, &self.weights.get(&format!("{p}w1")).data, self.cfg.d_ff());
                for v in ff.iter_mut() {
                    *v = gelu(*v);
                }
                tree_matvec_acc(&ff, &self.weights.get(&format!("{p}w2")).data, &mut x);
            }

            let xn = rmsnorm(&x, &self.weights.get("final_norm").data);
            let mut logits = vec![0.0f32; VOCAB];
            for (v, lo) in logits.iter_mut().enumerate() {
                *lo = tree_dot(&xn, &embed[v * d..(v + 1) * d]);
            }
            st.pos += 1;
            logits
        }
    }
}

#[test]
fn advance_batch_matches_fixed_tree_reference_bit_for_bit() {
    // Every model tier that differs structurally (layers/heads/width),
    // three lanes, 24 steps, exact f32 equality — against the in-test
    // fixed-tree derivation, for every kernel variant this CPU can run.
    for (name, seed) in [("nano", 1u64), ("tiny", 2), ("small", 3), ("medium", 4), ("large", 5)] {
        let cfg = by_name(name).unwrap();
        let weights = Weights::random(cfg, seed);
        let tree = tree_ref::Model::new(cfg, weights.clone());

        let n_lanes = 3;
        let steps = 24;
        let seqs: Vec<Vec<u32>> = (0..n_lanes).map(|l| golden_tokens(l, steps)).collect();

        // Pin the expectation once...
        let mut expected = vec![vec![0.0f32; n_lanes * VOCAB]; steps];
        let mut tl: Vec<tree_ref::Lane> =
            (0..n_lanes).map(|_| tree_ref::Lane::new(cfg, steps)).collect();
        for (t, exp) in expected.iter_mut().enumerate() {
            for (l, lane) in tl.iter_mut().enumerate() {
                exp[l * VOCAB..(l + 1) * VOCAB]
                    .copy_from_slice(&tree.advance(lane, seqs[l][t]));
            }
        }

        // ...then every kernel variant must reproduce it exactly.
        for opts in kernel_variants() {
            let model = NativeModel::with_opts(cfg, weights.clone(), opts).unwrap();
            let mut lanes: Vec<LaneState> =
                (0..n_lanes).map(|_| LaneState::new(cfg, steps)).collect();
            let mut scratch = Scratch::new(cfg, n_lanes);
            let mut out = vec![0.0f32; n_lanes * VOCAB];
            for (t, exp) in expected.iter().enumerate() {
                let toks: Vec<u32> = seqs.iter().map(|s| s[t]).collect();
                model.advance_batch(&mut lanes, &toks, &mut scratch, &mut out, VOCAB).unwrap();
                assert_eq!(
                    &out, exp,
                    "{name}: logits diverged from fixed tree at step {t} ({opts:?})"
                );
            }
        }
    }
}

#[test]
fn fixed_tree_stays_close_to_seed_reference() {
    // The seed implementation is frozen as a drift bound: the fixed-tree
    // reorder must change results only at round-off scale (same terms,
    // different addition order), never structurally.
    let cfg = by_name("small").unwrap();
    let weights = Weights::random(cfg, 3);
    let seedm = ReferenceModel::new(cfg, weights.clone());
    let tree = tree_ref::Model::new(cfg, weights);

    let toks = golden_tokens(0, 24);
    let mut rl = ReferenceLane::new(cfg, MAX_CONTEXT);
    let mut tl = tree_ref::Lane::new(cfg, MAX_CONTEXT);
    for (t, &tok) in toks.iter().enumerate() {
        let a = seedm.advance(&mut rl, tok).unwrap();
        let b = tree.advance(&mut tl, tok);
        for (v, (&sa, &sb)) in a.iter().zip(&b).enumerate() {
            assert!(
                (sa - sb).abs() <= 1e-2 * (1.0 + sa.abs()),
                "step {t} logit {v}: seed {sa} vs tree {sb} drifted structurally"
            );
        }
    }
}

#[test]
fn coded_head_matches_fixed_tree_cdf_exactly() {
    // The compressor's native engine computes only the 256 coded logit
    // rows; they must equal the fixed-tree full head bit for bit, and the
    // quantized CDF must match at every position — this is what keeps
    // streams cross-decodable.
    let cfg = by_name("small").unwrap();
    let weights = Weights::random(cfg, 6);
    let tree = tree_ref::Model::new(cfg, weights.clone());
    let mut coded = NativeExecutor::new(cfg, weights, 1).with_head_rows(CODED_BYTES);

    let toks = golden_tokens(0, 20);
    let mut tl = tree_ref::Lane::new(cfg, MAX_CONTEXT);
    for &t in &toks {
        let expected = tree.advance(&mut tl, t);
        let got = coded.step(&[t]).unwrap();
        assert_eq!(got[..CODED_BYTES], expected[..CODED_BYTES], "coded logit rows");
        assert_eq!(logits_to_cdf(&got), logits_to_cdf(&expected), "quantized CDF");
    }
}

#[test]
fn fixed_tree_bitstream_replaces_the_seed_bitstream() {
    let data = llmzip::textgen::quick_sample(300, 42);
    let cfg = by_name("nano").unwrap();
    let chunk = 32usize;
    let weights = Weights::random(cfg, 7);

    // Re-derived golden container: the fixed-tree reference driving the
    // seed encode pipeline (stepping, window framing, v1 envelope).
    let tree = tree_ref::Model::new(cfg, weights.clone());
    let tree_container = pipeline_compress(cfg.name, chunk, &data, |win, enc| {
        let mut lane_toks = vec![BOS];
        lane_toks.extend(win[..win.len() - 1].iter().map(|&b| b as u32));
        let mut lane = tree_ref::Lane::new(cfg, MAX_CONTEXT);
        for (t, &byte) in win.iter().enumerate() {
            let logits = tree.advance(&mut lane, lane_toks[t]);
            let cdf = logits_to_cdf(&logits);
            let s = byte as usize;
            enc.encode(cdf[s], cdf[s + 1] - cdf[s], CDF_TOTAL);
        }
    });

    // The modern engine decodes it...
    let modern = LlmCompressor::from_weights(cfg, weights.clone(), chunk, 2).unwrap();
    let back = modern.decompress(&tree_container).unwrap();
    assert_eq!(back, data, "fixed-tree golden container must decode bit-exactly");

    // ...and emits exactly this bitstream: the modern encoder's framed v2
    // envelope re-enveloped as v1 must reproduce the golden container
    // byte for byte (records, payload bytes, everything).
    let z = modern.compress(&data).unwrap();
    let mut parsed = Container::from_bytes(&z).unwrap();
    assert_eq!(parsed.version, llmzip::compress::CONTAINER_V2);
    parsed.version = llmzip::compress::CONTAINER_V1;
    parsed.flags = 0;
    assert_eq!(
        parsed.to_bytes(),
        tree_container,
        "modern encoder must emit the fixed-tree bitstream (v2 envelope aside)"
    );
    let reparsed = Container::from_bytes(&tree_container).unwrap();
    assert_eq!(reparsed.to_bytes(), tree_container, "v1 re-encodes byte-exactly");

    // The COMPATIBILITY BREAK, pinned on purpose: the pre-PR6 bitstream
    // (seed ascending-order reductions) is a different byte sequence.
    // Containers written before the fixed-tree kernels require a pre-PR6
    // build to decode; encoder and decoder moved together.
    let seedm = ReferenceModel::new(cfg, weights);
    let seed_container = pipeline_compress(cfg.name, chunk, &data, |win, enc| {
        let mut lane_toks = vec![BOS];
        lane_toks.extend(win[..win.len() - 1].iter().map(|&b| b as u32));
        let mut lane = ReferenceLane::new(cfg, MAX_CONTEXT);
        for (t, &byte) in win.iter().enumerate() {
            let logits = seedm.advance(&mut lane, lane_toks[t]).unwrap();
            let cdf = logits_to_cdf(&logits);
            let s = byte as usize;
            enc.encode(cdf[s], cdf[s + 1] - cdf[s], CDF_TOTAL);
        }
    });
    assert_ne!(
        seed_container, tree_container,
        "the fixed-tree refactor intentionally changed the bitstream"
    );
}

/// The seed encode pipeline (stream/window framing + v1 envelope) with a
/// caller-supplied per-window encoder.
fn pipeline_compress(
    cfg_name: &str,
    chunk_tokens: usize,
    data: &[u8],
    mut encode_window: impl FnMut(&[u8], &mut RangeEncoder),
) -> Vec<u8> {
    let stream_bytes = 4 * chunk_tokens; // from_weights' stream granularity
    let mut records = Vec::new();
    let mut payload = Vec::new();
    for stream in data.chunks(stream_bytes) {
        let mut enc = RangeEncoder::new();
        for win in stream.chunks(chunk_tokens) {
            encode_window(win, &mut enc);
        }
        let comp = enc.finish();
        records.push(ChunkRecord { comp_len: comp.len() as u32, n_tokens: stream.len() as u32 });
        payload.extend(comp);
    }
    Container::v1(
        data.len() as u64,
        crc32(data),
        chunk_tokens as u32,
        format!("{cfg_name}:0"), // ExecutorKind::Native flag
        records,
        payload,
    )
    .to_bytes()
}
