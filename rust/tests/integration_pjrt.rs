//! Integration tests over the PJRT runtime + real artifacts.
//!
//! These need `make artifacts`; they skip (with a message) when the
//! artifacts directory is missing so `cargo test` stays green on a fresh
//! checkout.

use llmzip::compress::{Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::lm::config::{self, by_name};
use llmzip::lm::executor::LmExecutor;
use llmzip::lm::native::{LaneState, NativeModel};
use llmzip::lm::ExecutorKind;
use llmzip::runtime::{ArtifactStore, PjrtForwardExecutor};
use llmzip::tokenizer::vocab::BOS;

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open(None) {
        Ok(s) if s.has_model("medium") => Some(s),
        _ => {
            eprintln!("SKIP: artifacts not built");
            None
        }
    }
}

fn softmax_256(logits: &[f32]) -> Vec<f32> {
    let m = logits[..256].iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let e: Vec<f32> = logits[..256].iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = e.iter().sum();
    e.into_iter().map(|x| x / s).collect()
}

#[test]
fn pjrt_forward_matches_native_model() {
    let Some(store) = store() else { return };
    let cfg = by_name("medium").unwrap();
    let fwd = PjrtForwardExecutor::from_store(&store, cfg).unwrap();
    let text = llmzip::experiments::human_text(llmzip::textgen::Domain::Wiki, 100);
    let mut lane = vec![BOS];
    lane.extend(text[..60].iter().map(|&b| b as u32));
    let lanes = vec![lane.clone()];
    let logits = fwd.encode_logits(&lanes, lane.len()).unwrap();

    let native = NativeModel::new(cfg, store.weights(cfg).unwrap());
    let mut st = LaneState::new(cfg, 256);
    for (t, &tok) in lane.iter().enumerate() {
        let nat = native.advance(&mut st, tok).unwrap();
        let pj = &logits[t * config::VOCAB..(t + 1) * config::VOCAB];
        // Different reduction orders: compare probabilities, not bits.
        let (pn, pp) = (softmax_256(&nat), softmax_256(pj));
        for (a, b) in pn.iter().zip(&pp) {
            assert!((a - b).abs() < 2e-3, "prob divergence at pos {t}: {a} vs {b}");
        }
    }
}

#[test]
fn pallas_artifact_matches_jnp_artifact() {
    let Some(store) = store() else { return };
    let cfg = by_name("medium").unwrap();
    // The pallas variant was lowered with batch=1; compare single-lane
    // logits against the jnp-lowered forward artifact.
    let exe = store.compile(&ArtifactStore::forward_pallas_file(cfg)).unwrap();
    let weights = store.weights(cfg).unwrap();
    let params = store.param_buffers(cfg, &weights).unwrap();
    let s = config::MAX_CONTEXT;
    let text = llmzip::experiments::human_text(llmzip::textgen::Domain::Novel, s);
    let mut tokens: Vec<i32> = vec![BOS as i32];
    tokens.extend(text[..s - 1].iter().map(|&b| b as i32));
    let tok_buf =
        store.client().unwrap().buffer_from_host_buffer::<i32>(&tokens, &[1, s], None).unwrap();
    let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
    args.push(&tok_buf);
    let res = exe.execute_b(&args).unwrap();
    let pallas_logits =
        res[0][0].to_literal_sync().unwrap().to_tuple1().unwrap().to_vec::<f32>().unwrap();

    let fwd = PjrtForwardExecutor::from_store(&store, cfg).unwrap();
    let lane: Vec<u32> = tokens.iter().map(|&t| t as u32).collect();
    let jnp_logits = fwd.encode_logits(&[lane], s).unwrap();

    let mut max_err = 0f32;
    for (a, b) in pallas_logits.iter().zip(&jnp_logits) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 2e-3, "pallas vs jnp artifact max err {max_err}");
}

#[test]
fn forward_prefix_replay_is_bit_exact() {
    // The decompression correctness property: running the forward artifact
    // on a prefix + padding gives bitwise the same logits at prefix
    // positions as running it on the full input.
    let Some(store) = store() else { return };
    let cfg = by_name("small").unwrap();
    let fwd = PjrtForwardExecutor::from_store(&store, cfg).unwrap();
    let text = llmzip::experiments::human_text(llmzip::textgen::Domain::Code, 300);
    let mut full = vec![BOS];
    full.extend(text[..200].iter().map(|&b| b as u32));
    let full_logits = fwd.encode_logits(&[full.clone()], full.len()).unwrap();
    let prefix: Vec<u32> = full[..97].to_vec();
    let prefix_logits = fwd.encode_logits(&[prefix.clone()], prefix.len()).unwrap();
    assert_eq!(
        &full_logits[..prefix.len() * config::VOCAB],
        &prefix_logits[..],
        "prefix logits must be bitwise identical"
    );
}

#[test]
fn cross_executor_roundtrips() {
    let Some(store) = store() else { return };
    let data = llmzip::experiments::human_text(llmzip::textgen::Domain::Clinical, 3000);
    for exec in [ExecutorKind::PjrtForward, ExecutorKind::PjrtStep, ExecutorKind::Native] {
        let comp = LlmCompressor::open(
            &store,
            LlmCompressorConfig {
                model: "small".into(),
                chunk_tokens: 128,
                stream_bytes: 1024,
                executor: exec,
                ..Default::default()
            },
        )
        .unwrap();
        let z = comp.compress(&data).unwrap();
        let back = comp.decompress(&z).unwrap();
        assert_eq!(back, data, "{exec:?}");
    }
}

#[test]
fn executor_mismatch_rejected() {
    let Some(store) = store() else { return };
    let data = llmzip::experiments::human_text(llmzip::textgen::Domain::Web, 600);
    let mk = |exec| {
        LlmCompressor::open(
            &store,
            LlmCompressorConfig {
                model: "small".into(),
                chunk_tokens: 128,
                stream_bytes: 1024,
                executor: exec,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let fwd = mk(ExecutorKind::PjrtForward);
    let step = mk(ExecutorKind::PjrtStep);
    let z = fwd.compress(&data).unwrap();
    let err = step.decompress(&z).unwrap_err().to_string();
    assert!(err.contains("executor"), "{err}");
    // And the matching executor decodes fine.
    assert_eq!(fwd.decompress(&z).unwrap(), data);
}

#[test]
fn step_and_forward_engines_agree_on_cost() {
    // The KV-cache step path and the batched forward path run different
    // HLO, so they are not bit-identical — but their probability streams
    // must be numerically close: compressed sizes within 2%.
    let Some(store) = store() else { return };
    let data = llmzip::experiments::human_text(llmzip::textgen::Domain::Novel, 4096);
    let sizes: Vec<usize> = [ExecutorKind::PjrtForward, ExecutorKind::PjrtStep]
        .into_iter()
        .map(|exec| {
            let comp = LlmCompressor::open(
                &store,
                LlmCompressorConfig {
                    model: "small".into(),
                    chunk_tokens: 256,
                    stream_bytes: 4096,
                    executor: exec,
                    ..Default::default()
                },
            )
            .unwrap();
            comp.compress(&data).unwrap().len()
        })
        .collect();
    let (a, b) = (sizes[0] as f64, sizes[1] as f64);
    assert!((a - b).abs() / a < 0.02, "forward {a} vs step {b}");
}

#[test]
fn compression_is_deterministic() {
    let Some(store) = store() else { return };
    let data = llmzip::experiments::human_text(llmzip::textgen::Domain::Math, 2000);
    let comp = LlmCompressor::open(
        &store,
        LlmCompressorConfig {
            model: "small".into(),
            chunk_tokens: 256,
            stream_bytes: 2048,
            executor: ExecutorKind::PjrtForward,
            ..Default::default()
        },
    )
    .unwrap();
    let a = comp.compress(&data).unwrap();
    let b = comp.compress(&data).unwrap();
    assert_eq!(a, b);
}

#[test]
fn generator_is_deterministic_and_byte_clean() {
    let Some(store) = store() else { return };
    let f = llmzip::sampling::DatasetFactory::from_store(&store, "small").unwrap();
    let a = f.generate_dataset(llmzip::textgen::Domain::Science, 4000, 0.7, 9).unwrap();
    let b = f.generate_dataset(llmzip::textgen::Domain::Science, 4000, 0.7, 9).unwrap();
    assert_eq!(a, b);
    assert!(a.iter().all(|&b| b == b'\n' || (0x00..0x80).contains(&b)));
}

#[test]
fn llm_beats_gzip_on_own_output() {
    // The paper's headline, end to end: model-generated text compresses far
    // better under the model than under gzip.
    let Some(store) = store() else { return };
    let f = llmzip::sampling::DatasetFactory::from_store(&store, "medium").unwrap();
    let data = f.generate_dataset(llmzip::textgen::Domain::Wiki, 16 * 1024, 0.7, 4).unwrap();
    let llm = LlmCompressor::open(
        &store,
        LlmCompressorConfig {
            model: "medium".into(),
            chunk_tokens: 256,
            stream_bytes: 4096,
            executor: ExecutorKind::PjrtForward,
            ..Default::default()
        },
    )
    .unwrap();
    let llm_ratio = data.len() as f64 / llm.compress(&data).unwrap().len() as f64;
    let gzip = llmzip::compress::baseline_by_name("gzip").unwrap();
    let gzip_ratio = data.len() as f64 / gzip.compress(&data).unwrap().len() as f64;
    assert!(
        llm_ratio > 1.5 * gzip_ratio,
        "llm {llm_ratio:.2}x must clearly beat gzip {gzip_ratio:.2}x"
    );
}
