//! Integration tests for the coordinator service (native engine — no
//! artifacts needed) including the TCP wire protocol.

use llmzip::compress::LlmCompressor;
use llmzip::coordinator::{BatchPolicy, Server, ServerConfig};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn native_server(lanes: usize) -> Server {
    Server::start(
        move || {
            let cfg = by_name("nano")?;
            LlmCompressor::from_weights(cfg, Weights::random(cfg, 99), 64, lanes)
        },
        ServerConfig {
            chunk_tokens: 64,
            policy: BatchPolicy { lanes, max_wait: Duration::from_millis(3) },
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn many_concurrent_clients_roundtrip() {
    let server = Arc::new(native_server(4));
    let handles: Vec<_> = (0..10)
        .map(|i| {
            let srv = server.clone();
            std::thread::spawn(move || {
                let data = llmzip::textgen::quick_sample(700 + i * 37, i as u64);
                for _ in 0..2 {
                    let z = srv.compress(&data).unwrap();
                    assert_eq!(srv.decompress(&z).unwrap(), data);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = &server.metrics;
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    assert!(m.mean_occupancy() > 0.25, "batching should pack lanes");
}

#[test]
fn mixed_sizes_including_edge_cases() {
    let server = native_server(2);
    for n in [0usize, 1, 63, 64, 65, 128, 1000] {
        let data = llmzip::textgen::quick_sample(n, n as u64);
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data, "n={n}");
    }
}

#[test]
fn failure_injection_bad_containers() {
    let server = native_server(2);
    // Garbage, truncations, and a valid container decoded twice.
    assert!(server.decompress(b"not a container").is_err());
    let data = llmzip::textgen::quick_sample(500, 3);
    let z = server.compress(&data).unwrap();
    assert!(server.decompress(&z[..z.len() / 2]).is_err());
    assert_eq!(server.decompress(&z).unwrap(), data);
    assert_eq!(server.decompress(&z).unwrap(), data, "decode is repeatable");
}

#[test]
fn server_survives_errors_and_keeps_serving() {
    let server = native_server(2);
    for _ in 0..3 {
        let _ = server.decompress(&[0xFF; 40]);
    }
    let data = llmzip::textgen::quick_sample(300, 5);
    let z = server.compress(&data).unwrap();
    assert_eq!(server.decompress(&z).unwrap(), data);
}
