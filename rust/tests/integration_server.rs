//! Integration tests for the coordinator service (native engine — no
//! artifacts needed): the replica pool, priority scheduling, bit-exactness
//! across pool configurations, and the TCP wire protocol.

use llmzip::compress::{Codec, Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::wire::{serve_connection, Client, MuxClient};
use llmzip::coordinator::{BatchPolicy, Op, Server, ServerConfig, WorkKind};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use llmzip::lm::ExecutorKind;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn native_server(lanes: usize) -> Server {
    Server::start(
        move || {
            let cfg = by_name("nano")?;
            LlmCompressor::from_weights(cfg, Weights::random(cfg, 99), 64, lanes)
        },
        ServerConfig {
            chunk_tokens: 64,
            policy: BatchPolicy { lanes, max_wait: Duration::from_millis(3) },
            ..Default::default()
        },
    )
    .unwrap()
}

/// Replica-pool server: `replicas` engine workers sharing ONE
/// `Arc<Weights>` bundle (f32 or int8-quantized — the compressor's
/// precision contract is taken from the bundle), each replica's native
/// engine running `threads` step-pool threads.
fn replica_server(replicas: usize, threads: usize, weights: Arc<Weights>) -> Server {
    let precision = weights.precision();
    Server::start(
        move || {
            LlmCompressor::from_shared(
                by_name("nano")?,
                weights.clone(),
                LlmCompressorConfig {
                    model: "nano".into(),
                    chunk_tokens: 64,
                    stream_bytes: 256,
                    executor: ExecutorKind::Native,
                    lanes: 4,
                    threads,
                    precision,
                    ..Default::default()
                },
            )
        },
        ServerConfig {
            chunk_tokens: 64,
            replicas,
            threads,
            policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(3) },
            ..Default::default()
        },
    )
    .unwrap()
}

/// Autoscaling server over shared weights: min 1, max 3, aggressive
/// timings so scale events happen inside a test run.
fn autoscale_server(weights: Arc<Weights>) -> Server {
    let precision = weights.precision();
    Server::start(
        move || {
            LlmCompressor::from_shared(
                by_name("nano")?,
                weights.clone(),
                LlmCompressorConfig {
                    model: "nano".into(),
                    chunk_tokens: 64,
                    stream_bytes: 256,
                    executor: ExecutorKind::Native,
                    lanes: 4,
                    threads: 1,
                    precision,
                    ..Default::default()
                },
            )
        },
        ServerConfig {
            chunk_tokens: 64,
            replicas: 2,
            min_replicas: 1,
            max_replicas: 3,
            autoscale: true,
            autoscale_cooldown: Duration::from_millis(10),
            autoscale_shrink_after: Duration::from_millis(20),
            policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(2) },
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn scale_to_min_with_queued_bulk_work_never_starves() {
    // Regression (scaling edge): shrink decisions require an EMPTY queue,
    // so a pool racing toward min_replicas can never strand queued bulk
    // work. Hammer an aggressively-shrinking server with bulk requests and
    // demand every one completes, with the floor respected throughout.
    let weights = Arc::new(Weights::random(by_name("nano").unwrap(), 99));
    let server = Arc::new(autoscale_server(weights));
    let mut handles = Vec::new();
    for i in 0..8u64 {
        let srv = server.clone();
        handles.push(std::thread::spawn(move || {
            for round in 0..3u64 {
                let data = llmzip::textgen::quick_sample(600 + i as usize * 31, i * 10 + round);
                let z = srv.compress(&data).unwrap();
                assert_eq!(srv.decompress(&z).unwrap(), data, "client {i} round {round}");
                // Idle gaps between rounds invite shrink attempts mid-run.
                std::thread::sleep(Duration::from_millis(30));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = &server.metrics;
    assert_eq!(m.errors.load(Ordering::Relaxed), 0, "{}", m.report());
    assert_eq!(m.requests.load(Ordering::Relaxed), 8 * 3 * 2);
    assert!(m.replicas_low.load(Ordering::Relaxed) >= 1, "floor violated: {}", m.report());
    assert!(m.replicas_peak.load(Ordering::Relaxed) <= 3, "ceiling violated: {}", m.report());
}

#[test]
fn legacy_empty_container_exemption_survives_autoscaled_pool() {
    // Regression (scaling edge): the pre-fix `model_name: ""` empty
    // container decodes through an AUTOSCALED pool too — the exemption
    // lives in admit, which never touches a worker for empty payloads, so
    // no scale state can break it.
    let weights = Arc::new(Weights::random(by_name("nano").unwrap(), 99));
    let server = autoscale_server(weights);
    let legacy = llmzip::compress::Container::v1(
        0,
        llmzip::util::crc32(b""),
        64,
        String::new(),
        vec![],
        vec![],
    )
    .to_bytes();
    assert_eq!(server.decompress(&legacy).unwrap(), b"");
    // And a server-produced empty container still carries the real tag.
    let z = server.compress(b"").unwrap();
    let c = llmzip::compress::Container::from_bytes(&z).unwrap();
    assert_eq!(c.model_name, "nano:0");
    assert_eq!(server.decompress(&z).unwrap(), b"");
}

#[test]
fn many_concurrent_clients_roundtrip() {
    let server = Arc::new(native_server(4));
    let handles: Vec<_> = (0..10)
        .map(|i| {
            let srv = server.clone();
            std::thread::spawn(move || {
                let data = llmzip::textgen::quick_sample(700 + i * 37, i as u64);
                for _ in 0..2 {
                    let z = srv.compress(&data).unwrap();
                    assert_eq!(srv.decompress(&z).unwrap(), data);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = &server.metrics;
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    assert!(m.mean_occupancy() > 0.25, "batching should pack lanes");
}

#[test]
fn mixed_sizes_including_edge_cases() {
    let server = native_server(2);
    for n in [0usize, 1, 63, 64, 65, 128, 1000] {
        let data = llmzip::textgen::quick_sample(n, n as u64);
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data, "n={n}");
    }
}

#[test]
fn failure_injection_bad_containers() {
    let server = native_server(2);
    // Garbage, truncations, and a valid container decoded twice.
    assert!(server.decompress(b"not a container").is_err());
    let data = llmzip::textgen::quick_sample(500, 3);
    let z = server.compress(&data).unwrap();
    assert!(server.decompress(&z[..z.len() / 2]).is_err());
    assert_eq!(server.decompress(&z).unwrap(), data);
    assert_eq!(server.decompress(&z).unwrap(), data, "decode is repeatable");
}

#[test]
fn server_survives_errors_and_keeps_serving() {
    let server = native_server(2);
    for _ in 0..3 {
        let _ = server.decompress(&[0xFF; 40]);
    }
    let data = llmzip::textgen::quick_sample(300, 5);
    let z = server.compress(&data).unwrap();
    assert_eq!(server.decompress(&z).unwrap(), data);
}

#[test]
fn multi_replica_concurrent_stress_lossless_with_latency_percentiles() {
    // >= 8 client threads firing mixed compress/decompress at a 3-replica
    // pool: every roundtrip must be lossless, no request may error, and
    // the decompress latency histogram must have recorded a p99.
    let weights = Arc::new(Weights::random(by_name("nano").unwrap(), 99));
    let server = Arc::new(replica_server(3, 1, weights));
    let mut handles = Vec::new();
    for i in 0..8u64 {
        let srv = server.clone();
        handles.push(std::thread::spawn(move || {
            let data = llmzip::textgen::quick_sample(500 + i as usize * 41, i);
            for round in 0..3u64 {
                let z = srv.compress(&data).unwrap();
                assert_eq!(srv.decompress(&z).unwrap(), data, "client {i} round {round}");
                if round == 0 {
                    // Interactive compress rides ahead of queued bulk work.
                    let zi = srv.compress_interactive(&data).unwrap();
                    assert_eq!(zi, z, "priority must not change the bytes");
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = &server.metrics;
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    assert_eq!(m.requests.load(Ordering::Relaxed), 8 * (3 * 2 + 1));
    assert!(m.latency_samples(WorkKind::Decompress) >= 24);
    let p50 = m.latency_percentile_ms(WorkKind::Decompress, 0.5);
    let p99 = m.latency_percentile_ms(WorkKind::Decompress, 0.99);
    assert!(p50 > 0.0 && p99 >= p50, "p50={p50} p99={p99}");
    assert!(m.latency_percentile_ms(WorkKind::Compress, 0.99) > 0.0);
    // Every dispatched batch is attributed to exactly one worker slot.
    let per_worker: u64 =
        m.workers.iter().map(|w| w.batches.load(Ordering::Relaxed)).sum();
    assert_eq!(per_worker, m.batches.load(Ordering::Relaxed));
}

#[test]
fn containers_bit_identical_across_replicas_threads_and_direct_path() {
    // The acceptance bar: containers are byte-identical for ANY
    // {replicas, threads} server configuration AND identical to the
    // direct (no-server) compressor path, which tests/golden_logits.rs
    // pins bit-for-bit to the frozen lm/reference.rs implementation.
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 99));
    // Multi-chunk payload (stream granularity 256 bytes -> 5 chunks).
    let data = llmzip::textgen::quick_sample(1200, 7);
    let direct = LlmCompressor::from_weights(cfg, weights.clone(), 64, 4).unwrap();
    let golden = direct.compress(&data).unwrap();
    let mut containers = Vec::new();
    for (replicas, threads) in [(1usize, 1usize), (2, 2), (4, 1)] {
        let server = replica_server(replicas, threads, weights.clone());
        let z = server.compress(&data).unwrap();
        assert_eq!(
            z, golden,
            "container bytes diverged at replicas={replicas} threads={threads}"
        );
        // Cross-decode: the server decodes the direct container and the
        // direct compressor decodes the server's.
        assert_eq!(server.decompress(&golden).unwrap(), data);
        containers.push(z);
    }
    for z in &containers {
        assert_eq!(direct.decompress(z).unwrap(), data);
    }
}

#[test]
fn server_empty_container_roundtrips_through_compressor() {
    // Regression (zero-length-compress fix): server-produced empty
    // containers carry the real `model:flag` tag and decode through
    // `LlmCompressor::decompress`.
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 99));
    let server = replica_server(2, 1, weights.clone());
    let z = server.compress(b"").unwrap();
    let direct = LlmCompressor::from_weights(cfg, weights, 64, 4).unwrap();
    assert_eq!(direct.container_tag(), "nano:0");
    assert_eq!(direct.decompress(&z).unwrap(), b"");
    assert_eq!(server.decompress(&z).unwrap(), b"");
}

/// The shared int8 bundle every quantized-server test uses: the
/// deterministic quantization of the same seed-99 nano weights as the f32
/// tests.
fn int8_weights() -> Arc<Weights> {
    Arc::new(Weights::random(by_name("nano").unwrap(), 99).quantize())
}

#[test]
fn int8_containers_bit_identical_across_replicas_threads_and_direct_path() {
    // The int8 acceptance bar mirrors the f32 one: containers are
    // byte-identical for ANY {replicas, threads} configuration and
    // identical to the direct compressor path; the int8 path is pinned by
    // determinism (integer accumulation) rather than a golden reference.
    let cfg = by_name("nano").unwrap();
    let weights = int8_weights();
    let data = llmzip::textgen::quick_sample(1200, 7);
    let direct = LlmCompressor::from_weights(cfg, weights.clone(), 64, 4).unwrap();
    assert!(direct.container_tag().starts_with("nano:0:q8:"), "{}", direct.container_tag());
    let golden = direct.compress(&data).unwrap();
    for (replicas, threads) in [(1usize, 1usize), (2, 2), (3, 1)] {
        let server = replica_server(replicas, threads, weights.clone());
        let z = server.compress(&data).unwrap();
        assert_eq!(z, golden, "int8 bytes diverged at replicas={replicas} threads={threads}");
        assert_eq!(server.decompress(&golden).unwrap(), data);
    }
    assert_eq!(direct.decompress(&golden).unwrap(), data);
}

#[test]
fn int8_server_rejects_foreign_fingerprint_with_clear_error_not_crc() {
    // A quantized container from DIFFERENT weights must be refused at
    // admit (tag mismatch names both engines), never decoded into a CRC
    // failure.
    let server = replica_server(1, 1, int8_weights());
    let data = llmzip::textgen::quick_sample(300, 8);
    let mut container =
        llmzip::compress::Container::from_bytes(&server.compress(&data).unwrap()).unwrap();
    assert!(container.model_name.starts_with("nano:0:q8:"));
    container.model_name = "nano:0:q8:0bad0bad".into();
    let err = server.decompress(&container.to_bytes()).unwrap_err().to_string();
    assert!(err.contains("produced by engine"), "{err}");
    assert!(!err.contains("CRC"), "{err}");
    // The direct compressor names the fingerprint explicitly.
    let direct = LlmCompressor::from_weights(
        by_name("nano").unwrap(),
        int8_weights(),
        64,
        4,
    )
    .unwrap();
    let err = direct.decompress(&container.to_bytes()).unwrap_err().to_string();
    assert!(err.contains("fingerprint"), "{err}");
}

#[test]
fn streamed_and_ticketed_containers_match_the_direct_path() {
    // The streaming session and the async ticket API are new FACES, not
    // new formats: both must produce the exact bytes of the direct
    // reference-pinned compressor.
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 99));
    let server = replica_server(2, 1, weights.clone());
    let direct = LlmCompressor::from_weights(cfg, weights, 64, 4).unwrap();
    let data = llmzip::textgen::quick_sample(1500, 12);
    let golden = direct.compress(&data).unwrap();
    // Ticketed one-shot.
    let ticket = server.submit(Op::Compress(data.clone().into())).unwrap();
    assert_eq!(ticket.wait().unwrap(), golden);
    // Streaming session, fed in awkward pieces.
    let mut stream = server.open_stream().unwrap();
    for piece in data.chunks(97) {
        stream.write_bytes(piece).unwrap();
    }
    let z = stream.finish().unwrap().wait().unwrap();
    assert_eq!(z, golden, "streamed bytes must equal the direct path");
    assert_eq!(direct.decompress(&z).unwrap(), data);
    // And the server's own incremental reader path agrees end-to-end.
    use std::io::Read as _;
    let mut back = Vec::new();
    direct.stream_decompress(&z[..]).unwrap().read_to_end(&mut back).unwrap();
    assert_eq!(back, data);
}

#[test]
fn server_decodes_v1_containers_byte_exactly() {
    // Old archives: the server accepts the legacy table-first layout
    // (same bitstream, older envelope) through the same admit path.
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 99));
    let server = replica_server(1, 1, weights);
    let data = llmzip::textgen::quick_sample(900, 13);
    let mut cont =
        llmzip::compress::Container::from_bytes(&server.compress(&data).unwrap()).unwrap();
    assert_eq!(cont.version, llmzip::compress::CONTAINER_V2);
    cont.version = llmzip::compress::CONTAINER_V1;
    cont.flags = 0;
    assert_eq!(server.decompress(&cont.to_bytes()).unwrap(), data);
}

/// Server with the buffer pool explicitly on or off (same engine,
/// weights and batching as [`replica_server`]): the pooling A/B fixture.
fn pooled_server(replicas: usize, pooling: bool, weights: Arc<Weights>, codec: Codec) -> Server {
    Server::start(
        move || {
            LlmCompressor::from_shared(
                by_name("nano")?,
                weights.clone(),
                LlmCompressorConfig {
                    model: "nano".into(),
                    chunk_tokens: 64,
                    stream_bytes: 256,
                    executor: ExecutorKind::Native,
                    lanes: 4,
                    threads: 1,
                    codec,
                    ..Default::default()
                },
            )
        },
        ServerConfig {
            chunk_tokens: 64,
            replicas,
            threads: 1,
            codec,
            pooling,
            policy: BatchPolicy { lanes: 4, max_wait: Duration::from_millis(3) },
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn containers_bit_identical_with_pooling_on_and_off() {
    // The zero-copy acceptance bar: buffer recycling changes where bytes
    // live, never their values. Containers (one-shot AND streamed) must
    // be byte-identical with the pool enabled and disabled, across
    // replica counts and both entropy backends, and the pooled server
    // must actually be recycling (hit counter moves).
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 99));
    let data = llmzip::textgen::quick_sample(1400, 19);
    for codec in [Codec::Range, Codec::Fse] {
        let mut golden: Option<Vec<u8>> = None;
        for replicas in [1usize, 3] {
            for pooling in [true, false] {
                let server = pooled_server(replicas, pooling, weights.clone(), codec);
                assert_eq!(server.pool().is_enabled(), pooling);
                let z = server.compress(&data).unwrap();
                match &golden {
                    None => golden = Some(z.clone()),
                    Some(g) => assert_eq!(
                        &z, g,
                        "bytes diverged at replicas={replicas} pooling={pooling} codec={codec:?}"
                    ),
                }
                assert_eq!(server.decompress(&z).unwrap(), data);
                // Streamed upload hits the pooled chunk-staging path.
                let mut stream = server.open_stream().unwrap();
                for piece in data.chunks(113) {
                    stream.write_bytes(piece).unwrap();
                }
                assert_eq!(&stream.finish().unwrap().wait().unwrap(), golden.as_ref().unwrap());
                let stats = server.pool().stats();
                if pooling {
                    assert!(
                        stats.hits > 0,
                        "pooled server never recycled a buffer: {stats:?}"
                    );
                } else {
                    assert_eq!(stats.hits, 0, "disabled pool must not recycle: {stats:?}");
                    assert_eq!(stats.returns, 0, "disabled pool must not retain: {stats:?}");
                }
            }
        }
    }
}

/// Spin a real TCP acceptor over `server` and return its address.
fn spawn_listener(server: Arc<Server>) -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        while let Ok((stream, _)) = listener.accept() {
            let srv = server.clone();
            std::thread::spawn(move || {
                let _ = serve_connection(stream, &*srv);
            });
        }
    });
    addr
}

#[test]
fn wire_v2_multiplexes_interleaved_requests_and_streams_on_one_connection() {
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 99));
    let server = Arc::new(replica_server(2, 1, weights.clone()));
    let direct = LlmCompressor::from_weights(cfg, weights, 64, 4).unwrap();
    let addr = spawn_listener(server);

    let a = llmzip::textgen::quick_sample(800, 21);
    let b = llmzip::textgen::quick_sample(500, 22);
    let c = llmzip::textgen::quick_sample(300, 23);
    let (za, zb, zc) =
        (direct.compress(&a).unwrap(), direct.compress(&b).unwrap(), direct.compress(&c).unwrap());

    let mut client = MuxClient::connect(&addr).unwrap();
    // Interleave: two compresses, a decompress, and a chunked stream
    // upload — all in flight on ONE connection before any response is
    // read.
    let id_a = client.submit_compress(&a).unwrap();
    let id_stream = client.open_stream().unwrap();
    let id_b = client.submit_compress_interactive(&b).unwrap();
    for piece in c.chunks(131) {
        client.stream_chunk(id_stream, piece).unwrap();
    }
    let id_dec = client.submit_decompress(&za).unwrap();
    client.stream_finish(id_stream).unwrap();

    let mut results: std::collections::HashMap<u32, Vec<u8>> = std::collections::HashMap::new();
    for _ in 0..4 {
        let (id, result) = client.recv().unwrap();
        results.insert(id, result.unwrap());
    }
    assert_eq!(results[&id_a], za, "mux compress bytes match the direct path");
    assert_eq!(results[&id_b], zb, "interactive priority must not change the bytes");
    assert_eq!(results[&id_dec], a, "mux decompress returns the original");
    assert_eq!(results[&id_stream], zc, "chunked upload equals one-shot bytes");

    // Errors come back as tagged frames, and the connection survives them.
    let bad = client.submit_decompress(b"not a container").unwrap();
    let (id, result) = client.recv().unwrap();
    assert_eq!(id, bad);
    assert!(result.is_err());
    let ok = client.submit_compress(&b).unwrap();
    let (id, result) = client.recv().unwrap();
    assert_eq!(id, ok);
    assert_eq!(result.unwrap(), zb);
}

#[test]
fn wire_v1_clients_still_speak_through_the_autodetect() {
    let cfg = by_name("nano").unwrap();
    let weights = Arc::new(Weights::random(cfg, 99));
    let server = Arc::new(replica_server(1, 1, weights));
    let addr = spawn_listener(server);
    let data = llmzip::textgen::quick_sample(600, 31);
    let mut client = Client::connect(&addr).unwrap();
    let z = client.compress(&data).unwrap();
    assert_eq!(client.decompress(&z).unwrap(), data);
    // Several requests on the same persistent v1 connection.
    let z2 = client.compress(&data).unwrap();
    assert_eq!(z2, z);
    // And a v2 client on a fresh connection to the same listener.
    let mut mux = MuxClient::connect(&addr).unwrap();
    let id = mux.submit_compress(&data).unwrap();
    let (rid, result) = mux.recv().unwrap();
    assert_eq!(rid, id);
    assert_eq!(result.unwrap(), z);
}

#[test]
fn int8_server_mixed_sizes_and_legacy_empty_exemption() {
    // Quantized servers serve the same edge cases as f32 ones, and the
    // legacy `model_name: ""` empty-container exemption is
    // precision-agnostic (no payload, nothing to mis-decode).
    let server = replica_server(2, 1, int8_weights());
    for n in [0usize, 1, 63, 64, 65, 500] {
        let data = llmzip::textgen::quick_sample(n, n as u64);
        let z = server.compress(&data).unwrap();
        assert_eq!(server.decompress(&z).unwrap(), data, "n={n}");
    }
    let legacy = llmzip::compress::Container::v1(
        0,
        llmzip::util::crc32(b""),
        64,
        String::new(),
        vec![],
        vec![],
    )
    .to_bytes();
    assert_eq!(server.decompress(&legacy).unwrap(), b"");
}
