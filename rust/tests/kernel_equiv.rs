//! Property suite for the SIMD kernel layer: every tier available on this
//! CPU must reproduce the scalar specification **bit for bit**, per
//! primitive and end to end.
//!
//! * f32 ops: bitwise equality (`to_bits`) — the vector tiers share the
//!   scalar path's fixed tree order, so this is equality by construction,
//!   not tolerance.
//! * i8 ops: the i32 accumulation is exact, so equality is plain `==`
//!   (also checked against an independent i64 reference).
//! * End to end: container bytes are identical across kernel tier × panel
//!   layout × lanes × threads on every textgen domain, for f32 and int8
//!   weights, and containers cross-decode between kernel variants.

use llmzip::compress::{Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::lm::config::by_name;
use llmzip::lm::kernels::{self, KernelTier, PanelF32, PanelI8};
use llmzip::lm::weights::Weights;
use llmzip::lm::{ExecutorKind, Precision};
use llmzip::textgen::{generate, Domain};
use llmzip::util::Pcg64;
use std::sync::Arc;

/// Scalar first (the specification), then the best tier this CPU has —
/// on a machine without SIMD this degenerates to `[Scalar]` and the suite
/// still pins the panel/no-panel and e2e invariants.
fn tiers() -> Vec<KernelTier> {
    let mut out = vec![KernelTier::Scalar];
    let best = KernelTier::detect();
    if best != KernelTier::Scalar {
        out.push(best);
    }
    out
}

fn rand_f32(rng: &mut Pcg64) -> f32 {
    (rng.next_u32() as f32 / u32::MAX as f32) * 2.0 - 1.0
}

fn rand_vec_f32(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rand_f32(rng)).collect()
}

fn rand_vec_i8(rng: &mut Pcg64, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.gen_range(255) as i64 - 127) as i8).collect()
}

/// Lengths that exercise full vector blocks, remainder lanes (1..7 for
/// f32, 1..15 for i8), the empty tail, and sub-block inputs.
const LENS: [usize; 20] =
    [1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 24, 31, 32, 33, 48, 63, 64, 96, 127, 128];

#[test]
fn dot_f32_bitwise_across_tiers() {
    let mut rng = Pcg64::seeded(11);
    for &len in &LENS {
        let a = rand_vec_f32(&mut rng, len);
        let b = rand_vec_f32(&mut rng, len);
        let want = kernels::dot_f32(KernelTier::Scalar, &a, &b);
        for t in tiers() {
            let got = kernels::dot_f32(t, &a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "dot_f32 len {len} tier {t:?}");
        }
        // All-zero and exactly-cancelling inputs: the padded vector tail
        // must not flip a +0.0 to -0.0 (sign bit is part of "bitwise").
        let zeros = vec![0.0f32; len];
        let negs: Vec<f32> = a.iter().map(|v| -v).collect();
        for (x, y) in [(&zeros, &b), (&a, &zeros), (&negs, &b)] {
            let want = kernels::dot_f32(KernelTier::Scalar, x, y);
            for t in tiers() {
                assert_eq!(
                    kernels::dot_f32(t, x, y).to_bits(),
                    want.to_bits(),
                    "dot_f32 zero/neg len {len} tier {t:?}"
                );
            }
        }
    }
}

#[test]
fn dot_i8_exact_across_tiers() {
    let mut rng = Pcg64::seeded(12);
    for &len in &LENS {
        let mut cases = vec![
            (rand_vec_i8(&mut rng, len), rand_vec_i8(&mut rng, len)),
            // Extremes: ±127 everywhere stresses the widening multiply
            // (127*127 overflows i16 pairwise sums if an implementation
            // ever tried to keep them narrow).
            (vec![127i8; len], vec![127i8; len]),
            (vec![-127i8; len], vec![127i8; len]),
        ];
        cases.push((vec![0i8; len], rand_vec_i8(&mut rng, len)));
        for (a, b) in &cases {
            let want: i64 = a.iter().zip(b).map(|(&x, &y)| x as i64 * y as i64).sum();
            for t in tiers() {
                let got = kernels::dot_i8(t, a, b);
                assert_eq!(got as i64, want, "dot_i8 len {len} tier {t:?}");
            }
        }
    }
}

#[test]
fn axpy_f32_bitwise_across_tiers() {
    let mut rng = Pcg64::seeded(13);
    for &len in &LENS {
        let x = rand_vec_f32(&mut rng, len);
        let y0 = rand_vec_f32(&mut rng, len);
        for a in [0.37f32, -1.25, 0.0, 1.0] {
            let mut want = y0.clone();
            kernels::axpy_f32(KernelTier::Scalar, a, &x, &mut want);
            for t in tiers() {
                let mut got = y0.clone();
                kernels::axpy_f32(t, a, &x, &mut got);
                let same = got.iter().zip(&want).all(|(g, w)| g.to_bits() == w.to_bits());
                assert!(same, "axpy_f32 len {len} a {a} tier {t:?}");
            }
        }
    }
}

#[test]
fn quantize_lanes_matches_scalar() {
    let mut rng = Pcg64::seeded(14);
    for &d in &LENS {
        let n = 3;
        let mut xs = rand_vec_f32(&mut rng, n * d);
        // Lane 1 all-zero: the contract is sx == 0.0 and zeroed codes
        // (downstream matmuls skip such lanes entirely).
        xs[d..2 * d].fill(0.0);
        // Spice lane 2 with large magnitudes and negative zero.
        for (i, v) in xs[2 * d..3 * d].iter_mut().enumerate() {
            *v *= 1000.0;
            if i % 7 == 3 {
                *v = -0.0;
            }
        }
        let mut want_q = vec![0i8; n * d];
        let mut want_s = vec![0.0f32; n];
        kernels::quantize_lanes(KernelTier::Scalar, n, d, &xs, &mut want_q, &mut want_s);
        assert_eq!(want_s[1], 0.0, "all-zero lane must get sx == 0");
        assert!(want_q[d..2 * d].iter().all(|&q| q == 0));
        for t in tiers() {
            let mut got_q = vec![0i8; n * d];
            let mut got_s = vec![0.0f32; n];
            kernels::quantize_lanes(t, n, d, &xs, &mut got_q, &mut got_s);
            assert_eq!(got_q, want_q, "codes d {d} tier {t:?}");
            let same = got_s.iter().zip(&want_s).all(|(g, w)| g.to_bits() == w.to_bits());
            assert!(same, "scales d {d} tier {t:?}");
        }
    }
}

/// Shapes with remainder rows/columns against both block widths (8-wide
/// f32 lanes, 4-wide panels, 16-wide i8 lanes).
const SHAPES: [(usize, usize); 8] =
    [(5, 3), (7, 9), (8, 4), (16, 12), (28, 8), (33, 17), (64, 48), (96, 40)];

#[test]
fn matmul_f32_panel_and_fallback_bitwise() {
    let mut rng = Pcg64::seeded(15);
    for &(d_in, d_out) in &SHAPES {
        for n in [1usize, 3] {
            let xs = rand_vec_f32(&mut rng, n * d_in);
            let w = rand_vec_f32(&mut rng, d_in * d_out);
            let base = rand_vec_f32(&mut rng, n * d_out); // accumulate semantics
            let panel = PanelF32::build(&w, d_in, d_out);

            let mut want = base.clone();
            kernels::matmul_f32(KernelTier::Scalar, n, d_in, d_out, &xs, &w, None, &mut want);
            for t in tiers() {
                for p in [Some(&panel), None] {
                    let mut got = base.clone();
                    kernels::matmul_f32(t, n, d_in, d_out, &xs, &w, p, &mut got);
                    let same =
                        got.iter().zip(&want).all(|(g, v)| g.to_bits() == v.to_bits());
                    assert!(
                        same,
                        "matmul_f32 {d_in}x{d_out} n {n} tier {t:?} panel {}",
                        p.is_some()
                    );
                }
            }
        }
    }
}

#[test]
fn matmul_i8_panel_and_fallback_bitwise() {
    let mut rng = Pcg64::seeded(16);
    for &(d_in, d_out) in &SHAPES {
        let n = 3;
        let wq = rand_vec_i8(&mut rng, d_in * d_out);
        let ws = rand_vec_f32(&mut rng, d_out);
        let mut xs = rand_vec_f32(&mut rng, n * d_in);
        xs[d_in..2 * d_in].fill(0.0); // sx == 0 lane: must be skipped, not zeroed
        let mut qx = vec![0i8; n * d_in];
        let mut sx = vec![0.0f32; n];
        kernels::quantize_lanes(KernelTier::Scalar, n, d_in, &xs, &mut qx, &mut sx);
        assert_eq!(sx[1], 0.0);
        let base = rand_vec_f32(&mut rng, n * d_out);
        let panel = PanelI8::build(&wq, d_in, d_out);

        let mut want = base.clone();
        let mut acc = vec![0i32; n * d_out];
        kernels::matmul_i8(
            KernelTier::Scalar, n, d_in, d_out, &wq, &ws, None, &qx, &sx, &mut acc, &mut want,
        );
        // The sx == 0 lane's outputs are exactly its `base` values.
        assert_eq!(want[d_out..2 * d_out], base[d_out..2 * d_out]);
        for t in tiers() {
            for p in [Some(&panel), None] {
                let mut got = base.clone();
                let mut acc = vec![0i32; n * d_out];
                kernels::matmul_i8(
                    t, n, d_in, d_out, &wq, &ws, p, &qx, &sx, &mut acc, &mut got,
                );
                let same = got.iter().zip(&want).all(|(g, v)| g.to_bits() == v.to_bits());
                assert!(
                    same,
                    "matmul_i8 {d_in}x{d_out} tier {t:?} panel {}",
                    p.is_some()
                );
            }
        }
    }
}

/// Compressor variants that must all emit the same container bytes:
/// kernel tier × panel layout × lane width × thread count.
fn variants() -> Vec<LlmCompressorConfig> {
    let mut out = Vec::new();
    for tier in tiers() {
        for panels in [true, false] {
            out.push(LlmCompressorConfig {
                chunk_tokens: 48,
                stream_bytes: 192,
                executor: ExecutorKind::Native,
                lanes: 4,
                threads: 2,
                kernel: Some(tier),
                panel_layout: panels,
                ..Default::default()
            });
        }
    }
    // Batching/parallelism sweeps ride on the best tier with panels on
    // (the production configuration).
    out.push(LlmCompressorConfig {
        chunk_tokens: 48,
        stream_bytes: 192,
        executor: ExecutorKind::Native,
        lanes: 1,
        threads: 1,
        kernel: None, // auto-resolve path
        panel_layout: true,
        ..Default::default()
    });
    out
}

#[test]
fn containers_identical_across_kernel_variants_all_domains() {
    let cfg = by_name("nano").unwrap();
    let f32_weights = Arc::new(Weights::random(cfg, 21));
    let i8_weights = Arc::new(f32_weights.quantize());

    let mut domains = Domain::EVAL.to_vec();
    domains.push(Domain::Tpch);

    for (precision, weights) in
        [(Precision::F32, &f32_weights), (Precision::Int8, &i8_weights)]
    {
        let comps: Vec<LlmCompressor> = variants()
            .into_iter()
            .map(|mut c| {
                c.precision = precision;
                LlmCompressor::from_shared_pooled(cfg, weights.clone(), c, None).unwrap()
            })
            .collect();
        for &domain in &domains {
            let data = generate(domain, 600, 77);
            let golden = comps[0].compress(&data).unwrap();
            for (i, comp) in comps.iter().enumerate().skip(1) {
                let z = comp.compress(&data).unwrap();
                assert_eq!(
                    z, golden,
                    "container bytes diverged: {precision:?} {domain:?} variant {i}"
                );
            }
            // Cross-decode: a forced-scalar/no-panel container decodes on
            // the best-tier engine and vice versa.
            let a = comps[0].decompress(&golden).unwrap();
            let b = comps.last().unwrap().decompress(&golden).unwrap();
            assert_eq!(a, data, "{precision:?} {domain:?}");
            assert_eq!(b, data, "{precision:?} {domain:?}");
        }
    }
}
