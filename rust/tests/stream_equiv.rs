//! Property suite for the streaming session API (PR 5 acceptance bar).
//!
//! * **Write-split invariance:** a [`CompressWriter`] fed the input at
//!   RANDOM split points — 1-byte writes, chunk-straddling writes, empty
//!   writes — emits a container byte-identical to the one-shot
//!   `compress()` of the same bytes, across every textgen domain, in f32
//!   AND int8. The one-shot path is itself pinned bit-for-bit to the
//!   frozen `lm/reference` implementation by `tests/golden_logits.rs`, so
//!   this transitively pins the streaming path to the golden bitstream.
//! * **Read-split invariance:** a [`DecompressReader`] drained at random
//!   read sizes reproduces the original bytes and verifies the CRC, for
//!   both container versions.
//! * **Random access:** `decompress_range(offset, len)` equals the same
//!   slice of the full decode for arbitrary ranges, and `decode_chunk(i)`
//!   equals the corresponding full-decode window — no whole-archive
//!   decoding anywhere.

use llmzip::compress::{Compressor, Container, LlmCompressor, LlmCompressorConfig};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::{Precision, Weights};
use llmzip::lm::ExecutorKind;
use llmzip::textgen::Domain;
use llmzip::util::Pcg64;
use std::io::{Read, Write};
use std::sync::Arc;

const CHUNK: usize = 32;
const STREAM: usize = 128;

fn compressor(precision: Precision) -> LlmCompressor {
    let cfg = by_name("nano").unwrap();
    let weights = Weights::random(cfg, 7);
    let weights = match precision {
        Precision::F32 => weights,
        Precision::Int8 => weights.quantize(),
    };
    LlmCompressor::from_shared(
        cfg,
        Arc::new(weights),
        LlmCompressorConfig {
            model: cfg.name.into(),
            chunk_tokens: CHUNK,
            stream_bytes: STREAM,
            executor: ExecutorKind::Native,
            lanes: 2,
            threads: 1,
            precision,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Cut `0..len` into random segments, seasoned with empty writes and
/// exact-boundary / straddling cuts.
fn random_splits(rng: &mut Pcg64, len: usize) -> Vec<usize> {
    let mut splits = Vec::new();
    let mut remaining = len;
    while remaining > 0 {
        let s = match rng.gen_index(6) {
            0 => 1,                              // byte-at-a-time
            1 => 0,                              // empty write
            2 => STREAM.min(remaining),          // exactly one chunk
            3 => (STREAM + 1).min(remaining),    // chunk-straddling
            _ => 1 + rng.gen_index(remaining.min(513)),
        };
        let s = s.min(remaining);
        splits.push(s);
        remaining -= s;
    }
    if rng.gen_bool(0.5) {
        splits.push(0); // trailing empty write
    }
    splits
}

fn stream_compress_with_splits(c: &LlmCompressor, data: &[u8], splits: &[usize]) -> Vec<u8> {
    let mut w = c.stream_compress(Vec::new()).unwrap();
    let mut off = 0;
    for &s in splits {
        // Exercise the std::io::Write face (what io::copy drives).
        w.write_all(&data[off..off + s]).unwrap();
        off += s;
    }
    assert_eq!(off, data.len());
    let (out, summary) = w.finish().unwrap();
    assert_eq!(summary.bytes_in, data.len() as u64);
    assert_eq!(summary.bytes_out, out.len() as u64);
    assert_eq!(summary.chunks, data.len().div_ceil(STREAM));
    out
}

#[test]
fn compress_writer_is_split_invariant_across_domains_f32_and_int8() {
    for precision in [Precision::F32, Precision::Int8] {
        let c = compressor(precision);
        let mut rng = Pcg64::seeded(0xC0FFEE + precision as u64);
        for (d, domain) in Domain::EVAL.iter().enumerate() {
            let size = 300 + rng.gen_index(700);
            let data = llmzip::textgen::generate(*domain, size, 40 + d as u64);
            let golden = c.compress(&data).unwrap();
            for round in 0..3 {
                let splits = random_splits(&mut rng, data.len());
                let z = stream_compress_with_splits(&c, &data, &splits);
                assert_eq!(
                    z, golden,
                    "{precision:?} {domain:?} round {round}: streamed bytes diverged \
                     (splits {splits:?})"
                );
            }
        }
        // Degenerate inputs: empty, one byte, exactly one chunk, exactly
        // two chunks.
        for data in [vec![], vec![65u8], vec![66u8; STREAM], vec![67u8; 2 * STREAM]] {
            let golden = c.compress(&data).unwrap();
            let splits: Vec<usize> = data.iter().map(|_| 1).collect();
            assert_eq!(stream_compress_with_splits(&c, &data, &splits), golden);
        }
    }
}

#[test]
fn decompress_reader_is_read_split_invariant_and_verifies() {
    for precision in [Precision::F32, Precision::Int8] {
        let c = compressor(precision);
        let mut rng = Pcg64::seeded(0xBEEF + precision as u64);
        let data = llmzip::textgen::quick_sample(900, 50);
        let v2 = c.compress(&data).unwrap();
        let v1 = {
            let mut cont = Container::from_bytes(&v2).unwrap();
            cont.version = llmzip::compress::CONTAINER_V1;
            cont.flags = 0;
            cont.to_bytes()
        };
        for z in [&v2, &v1] {
            for _ in 0..3 {
                let mut r = c.stream_decompress(&z[..]).unwrap();
                let mut back = Vec::new();
                loop {
                    let want = 1 + rng.gen_index(300);
                    let mut buf = vec![0u8; want];
                    let n = r.read(&mut buf).unwrap();
                    if n == 0 {
                        break;
                    }
                    back.extend_from_slice(&buf[..n]);
                }
                assert_eq!(back, data, "{precision:?}");
                assert!(r.verified(), "{precision:?}: EOF implies CRC verification");
            }
        }
    }
}

#[test]
fn decompress_range_equals_the_full_decode_slice() {
    for precision in [Precision::F32, Precision::Int8] {
        let c = compressor(precision);
        let data = llmzip::textgen::quick_sample(1000, 60);
        let z = c.compress(&data).unwrap();
        let full = c.decompress(&z).unwrap();
        assert_eq!(full, data);
        let mut rng = Pcg64::seeded(0xDECODE + precision as u64);
        // Structured ranges: chunk-aligned, chunk-straddling, single
        // bytes, whole input, empty.
        let mut ranges: Vec<(u64, u64)> = vec![
            (0, 0),
            (0, 1),
            (0, data.len() as u64),
            (data.len() as u64, 0),
            (STREAM as u64 - 1, 2),
            (STREAM as u64, STREAM as u64),
            (3 * STREAM as u64 + 7, 100),
        ];
        for _ in 0..12 {
            let off = rng.gen_index(data.len() + 1) as u64;
            let len = rng.gen_index(data.len() + 1 - off as usize) as u64;
            ranges.push((off, len));
        }
        for (off, len) in ranges {
            let got = c.decompress_range(&z, off, len).unwrap();
            assert_eq!(
                got,
                &full[off as usize..(off + len) as usize],
                "{precision:?} range [{off}, {off}+{len})"
            );
        }
        // Out-of-bounds ranges are refused, not truncated.
        assert!(c.decompress_range(&z, 0, data.len() as u64 + 1).is_err());
        assert!(c.decompress_range(&z, data.len() as u64, 1).is_err());
        assert!(c.decompress_range(&z, u64::MAX, 2).is_err());
    }
}

#[test]
fn decode_chunk_random_access_matches_full_decode_windows() {
    let c = compressor(Precision::F32);
    let data = llmzip::textgen::quick_sample(1100, 61);
    let z = c.compress(&data).unwrap();
    let container = Container::from_bytes(&z).unwrap();
    let full = c.decompress(&z).unwrap();
    let n_chunks = data.len().div_ceil(STREAM);
    assert_eq!(container.chunks.len(), n_chunks);
    // Decode chunks in a scrambled order — each must equal its window of
    // the full decode, independent of what was decoded before it.
    let order: Vec<usize> = (0..n_chunks).rev().collect();
    for i in order {
        let got = c.decode_chunk(&container, i).unwrap();
        let lo = i * STREAM;
        let hi = (lo + STREAM).min(data.len());
        assert_eq!(got, &full[lo..hi], "chunk {i}");
    }
    assert!(c.decode_chunk(&container, n_chunks).is_err());
}

#[test]
fn positioned_range_decode_from_file_reads_only_touched_frames() {
    use llmzip::compress::{FileSource, SeekableContainer};
    let c = compressor(Precision::F32);
    let data = llmzip::textgen::quick_sample(1000, 63);
    let z = c.compress(&data).unwrap();
    let full = c.decompress(&z).unwrap();
    let path = std::env::temp_dir()
        .join(format!("llmzip-stream-equiv-{}.lmz", std::process::id()));
    std::fs::write(&path, &z).unwrap();
    let file = FileSource::open(&path).unwrap();

    // A fresh open per range isolates the byte/frame counters.
    for (off, len, want_frames) in [
        (0u64, 1u64, 1u64),                     // first byte → first frame
        (STREAM as u64 - 1, 2, 2),              // straddle → two frames
        (3 * STREAM as u64 + 7, 50, 1),         // interior → one frame
        (0, 1000, 8),                           // everything → all 8 frames
        (500, 0, 0),                            // empty → nothing
    ] {
        let cont = SeekableContainer::open(&file).unwrap();
        let opened_bytes = cont.bytes_read();
        let got = c.decompress_range_from(&cont, off, len).unwrap();
        assert_eq!(got, &full[off as usize..(off + len) as usize], "[{off}, {off}+{len})");
        assert_eq!(cont.frames_read(), want_frames, "[{off}, {off}+{len})");
        // The decode touched header + trailer + exactly the frames in
        // range — never the whole file (except the all-frames range).
        let frame_bytes: u64 = cont
            .chunks_in_range(off, len)
            .unwrap()
            .map(|i| 9 + cont.records()[i].comp_len as u64)
            .sum();
        assert_eq!(cont.bytes_read(), opened_bytes + frame_bytes);
        if want_frames < 8 {
            assert!(
                cont.bytes_read() < z.len() as u64,
                "ranged decode read the whole container"
            );
        }
    }

    // decode_chunk_from equals the corresponding full-decode window and
    // fetches exactly one frame.
    let cont = SeekableContainer::open(&file).unwrap();
    for i in (0..cont.n_chunks()).rev() {
        let got = c.decode_chunk_from(&cont, i).unwrap();
        let lo = i * STREAM;
        let hi = (lo + STREAM).min(data.len());
        assert_eq!(got, &full[lo..hi], "chunk {i}");
    }
    assert_eq!(cont.frames_read(), cont.n_chunks() as u64);
    assert!(c.decode_chunk_from(&cont, cont.n_chunks()).is_err());

    // The slice-backed path routes v2 through the same machinery and
    // stays equal to the full-decode slice.
    assert_eq!(c.decompress_range(&z, 130, 77).unwrap(), &full[130..207]);
    std::fs::remove_file(&path).ok();
}

#[test]
fn range_decode_rejects_foreign_and_mismatched_containers() {
    // Random access rides the same contract checks as the full path:
    // model/executor/precision mismatches are refused by name, not
    // decoded into garbage.
    let f32c = compressor(Precision::F32);
    let q8c = compressor(Precision::Int8);
    let data = llmzip::textgen::quick_sample(400, 62);
    let z8 = q8c.compress(&data).unwrap();
    let err = f32c.decompress_range(&z8, 0, 10).unwrap_err().to_string();
    assert!(err.contains("precision"), "{err}");
    let container = Container::from_bytes(&z8).unwrap();
    let err = f32c.decode_chunk(&container, 0).unwrap_err().to_string();
    assert!(err.contains("precision"), "{err}");
    // Same-engine access works on both faces.
    assert_eq!(q8c.decompress_range(&z8, 1, 5).unwrap(), &data[1..6]);
}
