//! Deterministic stress/property harness for the ELASTIC replica pool.
//!
//! A seeded mixed-op load (bulk + interactive compress and decompress,
//! across every textgen domain) hammers an autoscaling server with
//! aggressive grow/shrink timings, forcing scale churn mid-traffic. The
//! pinned property: **every container the server produces is byte-identical
//! to the direct single-engine compressor path** — which
//! `tests/golden_logits.rs` pins bit-for-bit to the frozen `lm/reference`
//! implementation — no matter which `{replicas, threads, lanes, autoscale
//! event}` history happened to serve it. Scaling must also stay provably
//! bounded: never below `min_replicas`, never above `max_replicas`, and
//! error-free.
//!
//! The timings force churn but the ASSERTIONS never depend on timing:
//! byte-identity and bounds hold for every possible interleaving.

use llmzip::compress::{Compressor, LlmCompressor, LlmCompressorConfig};
use llmzip::coordinator::{BatchPolicy, Server, ServerConfig};
use llmzip::lm::config::by_name;
use llmzip::lm::weights::Weights;
use llmzip::lm::{ExecutorKind, StepPool};
use llmzip::textgen::Domain;
use llmzip::util::Pcg64;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

const CHUNK: usize = 64;
const STREAM: usize = 256;
const LANES: usize = 4;

fn replica_cfg() -> LlmCompressorConfig {
    LlmCompressorConfig {
        model: "nano".into(),
        chunk_tokens: CHUNK,
        stream_bytes: STREAM,
        executor: ExecutorKind::Native,
        lanes: LANES,
        threads: 1,
        precision: llmzip::lm::Precision::F32,
        ..Default::default()
    }
}

/// Elastic server over `weights`, optionally fanning every replica's steps
/// into one shared work-stealing [`StepPool`].
fn elastic_server(weights: Arc<Weights>, pool: Option<Arc<StepPool>>) -> Server {
    let precision = weights.precision();
    Server::start(
        move || {
            let mut cfg = replica_cfg();
            cfg.precision = precision;
            LlmCompressor::from_shared_pooled(
                by_name("nano")?,
                weights.clone(),
                cfg,
                pool.clone(),
            )
        },
        ServerConfig {
            chunk_tokens: CHUNK,
            replicas: 1,
            min_replicas: 1,
            max_replicas: 4,
            autoscale: true,
            autoscale_cooldown: Duration::from_millis(15),
            autoscale_shrink_after: Duration::from_millis(30),
            policy: BatchPolicy { lanes: LANES, max_wait: Duration::from_millis(2) },
            ..Default::default()
        },
    )
    .unwrap()
}

/// The direct single-engine reference path (same weights, same window and
/// stream granularity as the server replicas).
fn direct(weights: Arc<Weights>) -> LlmCompressor {
    LlmCompressor::from_weights(by_name("nano").unwrap(), weights, CHUNK, LANES).unwrap()
}

/// One client's seeded op stream: every compress is checked byte-for-byte
/// against the direct path, every decompress for losslessness.
fn client_ops(server: &Server, reference: &LlmCompressor, seed: u64, ops: usize) {
    let mut rng = Pcg64::seeded(seed);
    for op in 0..ops {
        let domain = Domain::EVAL[rng.gen_index(Domain::EVAL.len())];
        // Always > one stream chunk, so concurrent ops genuinely queue.
        let size = 300 + rng.gen_index(800);
        let data = llmzip::textgen::generate(domain, size, seed * 1000 + op as u64);
        let golden = reference.compress(&data).unwrap();
        match rng.gen_index(3) {
            0 => {
                let z = server.compress(&data).unwrap();
                assert_eq!(z, golden, "bulk bytes diverged: {domain:?} seed {seed} op {op}");
            }
            1 => {
                let z = server.compress_interactive(&data).unwrap();
                assert_eq!(
                    z, golden,
                    "interactive bytes diverged: {domain:?} seed {seed} op {op}"
                );
            }
            _ => {
                assert_eq!(
                    server.decompress(&golden).unwrap(),
                    data,
                    "decode diverged: {domain:?} seed {seed} op {op}"
                );
            }
        }
    }
}

/// Burst phase + quiet phase against one elastic server; returns once both
/// a grow and a shrink have been observed (with a hard deadline).
fn churn_and_verify(server: Arc<Server>, weights: Arc<Weights>, clients: u64) {
    // Phase 1 — burst: concurrent seeded clients queue far more chunk
    // items than one replica's lanes, forcing growth while every byte is
    // checked against the reference.
    let mut handles = Vec::new();
    for c in 0..clients {
        let srv = server.clone();
        let w = weights.clone();
        handles.push(std::thread::spawn(move || {
            let reference = direct(w);
            client_ops(&srv, &reference, c, 6);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = &server.metrics;
    assert_eq!(m.errors.load(Ordering::Relaxed), 0, "{}", m.report());
    assert!(
        m.scale_ups.load(Ordering::Relaxed) >= 1,
        "burst never grew the pool: {}",
        m.report()
    );

    // Phase 2 — quiet: idle trickle until the pool shrinks back.
    let reference = direct(weights.clone());
    let deadline = Instant::now() + Duration::from_secs(15);
    let mut tick = 0u64;
    while m.scale_downs.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "pool never shrank: {}", m.report());
        std::thread::sleep(Duration::from_millis(25));
        // A trickle op mid-shrink must still be byte-identical.
        if tick % 4 == 0 {
            let data = llmzip::textgen::quick_sample(150, 999 + tick);
            assert_eq!(server.compress(&data).unwrap(), reference.compress(&data).unwrap());
        }
        tick += 1;
    }

    // Bounds + integrity over the whole churn history.
    assert!(m.replicas_peak.load(Ordering::Relaxed) <= 4, "{}", m.report());
    assert!(m.replicas_low.load(Ordering::Relaxed) >= 1, "{}", m.report());
    assert_eq!(m.errors.load(Ordering::Relaxed), 0, "{}", m.report());

    // Final sweep: after all scaling events, one container per domain must
    // still match the reference exactly and roundtrip.
    for (i, domain) in Domain::EVAL.iter().enumerate() {
        let data = llmzip::textgen::generate(*domain, 400, 7_000 + i as u64);
        let golden = reference.compress(&data).unwrap();
        let z = server.compress(&data).unwrap();
        assert_eq!(z, golden, "{domain:?} after churn");
        assert_eq!(server.decompress(&z).unwrap(), data, "{domain:?} roundtrip");
    }
}

#[test]
fn elastic_stress_containers_byte_identical_under_scale_churn() {
    let weights = Arc::new(Weights::random(by_name("nano").unwrap(), 99));
    let server = Arc::new(elastic_server(weights.clone(), None));
    churn_and_verify(server, weights, 6);
}

#[test]
fn elastic_stress_with_shared_steal_pool() {
    // Same harness, but every replica fans its steps into ONE shared
    // work-stealing StepPool — autoscale churn + span stealing together
    // must still be invisible in the bytes.
    let weights = Arc::new(Weights::random(by_name("nano").unwrap(), 99));
    let pool = StepPool::new(3);
    let server = Arc::new(elastic_server(weights.clone(), Some(pool)));
    churn_and_verify(server, weights, 6);
}

#[test]
fn elastic_stress_int8_shared_pool() {
    // The quantized path under the same churn: int8 containers are pinned
    // by integer-accumulation determinism rather than the golden
    // reference, so byte-identity against the direct int8 path is the
    // contract.
    let weights = Arc::new(Weights::random(by_name("nano").unwrap(), 99).quantize());
    let pool = StepPool::new(2);
    let server = Arc::new(elastic_server(weights.clone(), Some(pool)));
    // Lighter load (int8 nano steps cost more in debug builds).
    churn_and_verify(server, weights, 4);
}
