//! Offline shim for the `anyhow` crate.
//!
//! This build environment has no crates.io access, so the subset of the
//! real `anyhow` API that llmzip uses is reimplemented here: [`Error`],
//! [`Result`], [`anyhow!`] and [`bail!`], plus the blanket
//! `From<E: std::error::Error>` conversion that makes `?` work on any
//! standard error type. Like the real crate, [`Error`] deliberately does
//! NOT implement `std::error::Error` — that is what keeps the blanket
//! `From` impl coherent with `impl<T> From<T> for T`.
//!
//! Differences from the real crate: no backtraces, no source chains and no
//! `Context` trait (llmzip does not use them). Messages are captured
//! eagerly as strings, which is exactly what llmzip's error paths do
//! anyway. Replacing this shim with the real `anyhow` is a one-line change
//! in `rust/Cargo.toml`.

use std::fmt;

/// A string-backed error value, compatible with `anyhow::Error` for every
/// operation llmzip performs (`Display`, `{:#}`, `Debug`, `to_string`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from an eagerly formatted message (used by [`anyhow!`]).
    pub fn from_string(msg: String) -> Error {
        Error { msg }
    }

    /// Build from any displayable value (used by the single-expression
    /// [`anyhow!`] form).
    pub fn from_display<T: fmt::Display>(value: T) -> Error {
        Error { msg: value.to_string() }
    }

    /// `anyhow::Error::msg` compatibility constructor.
    pub fn msg<T: fmt::Display>(value: T) -> Error {
        Error::from_display(value)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on real anyhow prints the whole cause chain; this shim has
        // no chain, so plain and alternate formats coincide.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result` — the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::from_string(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::from_display(&$err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::from_string(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let r: Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "disk on fire"));
        r?;
        Ok(())
    }

    fn bails(x: usize) -> Result<usize> {
        if x == 0 {
            bail!("x must be nonzero, got {x}");
        }
        Ok(x)
    }

    #[test]
    fn formats_and_conversions() {
        let e = anyhow!("plain {} message {}", 1, "two");
        assert_eq!(e.to_string(), "plain 1 message two");
        let n = 7;
        let e = anyhow!("captured {n}");
        assert_eq!(format!("{e:#}"), "captured 7");
        let s = String::from("already a string");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "already a string");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn bail_returns_early() {
        assert_eq!(bails(3).unwrap(), 3);
        assert!(bails(0).unwrap_err().to_string().contains("nonzero"));
    }
}
