//! Offline no-op stub of the `xla` crate (PJRT bindings).
//!
//! This build environment has neither crates.io access nor the
//! `xla_extension` shared library, so the exact API surface that
//! `llmzip::runtime` uses is stubbed here. Every entry point —
//! [`PjRtClient::cpu`] — fails with a clear runtime error, which makes all
//! PJRT executors degrade gracefully: `ArtifactStore` still opens and
//! serves `.lmz` weights to the native engine (its PJRT client is lazy),
//! while compile/upload paths error cleanly, so PJRT benches print their
//! SKIP line and PJRT integration tests skip. No PJRT code path can
//! silently produce wrong results because no buffer or executable can ever
//! be constructed.
//!
//! Swap this stub for the real bindings by editing one line in
//! `rust/Cargo.toml`; the types and signatures below mirror the
//! `xla_extension 0.5.x` subset llmzip calls.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT runtime not available: built against the vendored no-op xla stub \
     (rust/vendor/xla); use the native executor or link the real xla crate";

/// Stub error type; `Display` carries the message the caller formats.
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Element types accepted by device-buffer upload/download.
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// PJRT client handle (never constructible in the stub).
#[derive(Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real crate spins up the PJRT CPU plugin; the stub always fails,
    /// which is the single choke point that disables every PJRT path.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

/// Device buffer handle (never constructible in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Loaded executable handle (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// Host literal (never constructible in the stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module proto (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// HLO computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("vendored no-op xla stub"), "{err}");
        let err = HloModuleProto::from_text_file("/tmp/nope.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("PJRT runtime not available"));
    }
}
